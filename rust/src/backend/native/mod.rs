//! The pure-Rust CPU backend.
//!
//! Executes the full per-layer operation set (embed, RMSNorm, RoPE causal
//! attention, SwiGLU FFN, dense and CURed linear chains, calibration Σx²
//! taps, the tied LM head) plus the train and layer-heal optimizer steps
//! directly against host tensors — no artifacts, no Python, no external
//! runtime. Hot-path matmuls are blocked and multithreaded
//! ([`math`]); set `CURING_THREADS` to pin the worker count.
//!
//! This backend defines the reference semantics of the model family; the
//! `pjrt` artifact backend must agree with it.
//!
//! Two forward paths exist: the cached path behind `layer_forward` (the
//! train/heal reference, keeps every backward intermediate) and the
//! inference path behind `layer_forward_infer`/`layer_prefill`/
//! `layer_decode_batch` (no backward caches, scratch buffers reused
//! across layer calls, process-wide RoPE table cache, fused multi-slot
//! decode against ring-buffer K/V). Both produce identical outputs; the
//! parity tests below assert it.

mod forward;
pub mod math;
mod switched;
mod train;

use crate::backend::{Backend, CalibOut, HealOut, KvCache, KvPolicy, LayerParams, StepMode};
use crate::linalg::Mat;
use crate::model::ModelConfig;
use crate::tensor::{Tensor, TensorStore};
use crate::util::Json;
use anyhow::{bail, ensure, Result};
use std::cell::{Cell, RefCell};

/// Built-in model-family manifest: the native backend needs no artifacts
/// directory, so the configurations ship with the binary. `tiny` mirrors
/// the AOT build's headline config; `mini` is a fast-test size.
const NATIVE_MANIFEST: &str = r#"{
  "backend": "native",
  "configs": {
    "tiny": {"vocab": 512, "d_model": 256, "n_layers": 8, "n_heads": 8,
             "d_inter": 704, "seq": 64, "batch": 8, "ranks": [8, 16, 32],
             "default_rank": 16, "lora_rank": 1, "mora_rank": 16,
             "total_params": 6557952},
    "mini": {"vocab": 384, "d_model": 32, "n_layers": 4, "n_heads": 4,
             "d_inter": 64, "seq": 32, "batch": 2, "ranks": [4, 8],
             "default_rank": 8, "lora_rank": 1, "mora_rank": 8,
             "total_params": 53536}
  }
}"#;

pub struct NativeBackend {
    manifest: Json,
    execs: Cell<u64>,
    /// Inference-path scratch, shared across layer calls so eval/serve
    /// forwards allocate nothing but their outputs after warmup.
    scratch: RefCell<forward::InferScratch>,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend {
            // curlint: allow(panic) -- NATIVE_MANIFEST is a compile-time constant; parse failure is a build defect
            manifest: Json::parse(NATIVE_MANIFEST).expect("builtin manifest parses"),
            execs: Cell::new(0),
            scratch: RefCell::new(forward::InferScratch::new()),
        }
    }

    fn tick(&self) {
        self.execs.set(self.execs.get() + 1);
    }

    fn xdims(x: &Tensor) -> Result<(usize, usize, usize)> {
        ensure!(x.shape.len() == 3, "expected (b, s, d) input, got {:?}", x.shape);
        Ok((x.shape[0], x.shape[1], x.shape[2]))
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Json {
        &self.manifest
    }

    fn exec_count(&self) -> u64 {
        self.execs.get()
    }

    fn embed(&self, _cfg: &ModelConfig, emb: &Tensor, tokens: &Tensor) -> Result<Tensor> {
        self.tick();
        ensure!(tokens.shape.len() == 2, "tokens must be (b, s), got {:?}", tokens.shape);
        ensure!(emb.shape.len() == 2, "emb must be (vocab, d), got {:?}", emb.shape);
        let (b, s) = (tokens.shape[0], tokens.shape[1]);
        let (vocab, d) = (emb.shape[0], emb.shape[1]);
        let mut out = vec![0.0f32; b * s * d];
        forward::embed_gather(emb.f32s()?, vocab, d, tokens.i32s()?, &mut out)?;
        Ok(Tensor::from_f32(&[b, s, d], out))
    }

    fn layer_forward(&self, cfg: &ModelConfig, p: &LayerParams, x: &Tensor) -> Result<Tensor> {
        self.tick();
        let (b, s, d) = Self::xdims(x)?;
        let dims = forward::layer_dims(cfg.n_heads, p, b, s, d)?;
        let cache = forward::layer_forward_cached(dims, p, x.f32s()?)?;
        Ok(Tensor::from_f32(&x.shape, cache.y))
    }

    // curlint: hot-entry
    fn layer_forward_infer(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
    ) -> Result<Tensor> {
        self.tick();
        let (b, s, d) = Self::xdims(x)?;
        let dims = forward::layer_dims(cfg.n_heads, p, b, s, d)?;
        let mut sc = self.scratch.borrow_mut();
        let y = forward::layer_infer_impl(dims, p, x.f32s()?, None, &mut sc)?;
        Ok(Tensor::from_f32(&x.shape, y))
    }

    fn supports_kv_decode(&self) -> bool {
        true
    }

    fn fixed_shape(&self) -> bool {
        false
    }

    // curlint: hot-entry
    fn layer_prefill(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
        kv: &mut KvCache,
        layer: usize,
        slot: usize,
    ) -> Result<Tensor> {
        self.tick();
        let (b, w, d) = Self::xdims(x)?;
        ensure!(b == 1, "prefill input must be (1, w, d), got {:?}", x.shape);
        ensure!(
            w >= 1 && w <= kv.window && kv.d == d,
            "kv cache is (window={}, d={}), prefill input is ({w}, {d})",
            kv.window,
            kv.d
        );
        ensure!(slot < kv.b, "slot {slot} out of cache lanes 0..{}", kv.b);
        ensure!(
            kv.next_pos[slot] == 0,
            "slot {slot} already holds {} positions — reset_slot before re-prefilling",
            kv.next_pos[slot]
        );
        ensure!(layer < kv.n_layers(), "layer {layer} beyond kv cache ({})", kv.n_layers());
        let dims = forward::layer_dims(cfg.n_heads, p, 1, w, d)?;
        let mut sc = self.scratch.borrow_mut();
        // Prompt positions 0..w never wrap (w <= window <= cap): the
        // slot's lane prefix is plain row-major.
        let lane = slot * kv.cap * d;
        let (kc, vc) = (
            &mut kv.k[layer][lane..lane + w * d],
            &mut kv.v[layer][lane..lane + w * d],
        );
        let y = forward::layer_infer_impl(dims, p, x.f32s()?, Some((kc, vc)), &mut sc)?;
        Ok(Tensor::from_f32(&x.shape, y))
    }

    // curlint: hot-entry
    fn layer_decode_batch(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
        kv: &mut KvCache,
        layer: usize,
        slots: &[usize],
    ) -> Result<Tensor> {
        self.tick();
        let (n, s1, d) = Self::xdims(x)?;
        ensure!(s1 == 1, "decode input must be (n, 1, d), got {:?}", x.shape);
        ensure!(kv.d == d, "kv cache is d={}, decode input is d={d}", kv.d);
        ensure!(layer < kv.n_layers(), "layer {layer} beyond kv cache ({})", kv.n_layers());
        ensure!(slots.len() == n, "need one slot per input row");
        let mut sc = self.scratch.borrow_mut();
        // Validate every row before touching any cache state, so a bad
        // batch errors without leaving position maps half-updated. The
        // row buffer lives on the scratch so steady-state decode does
        // not allocate for batch metadata (an early error forfeits the
        // capacity for one step, nothing else).
        let mut rows = std::mem::take(&mut sc.rows);
        rows.clear();
        for (r, &slot) in slots.iter().enumerate() {
            ensure!(slot < kv.b, "slot {slot} out of cache lanes 0..{}", kv.b);
            ensure!(
                !slots[..r].contains(&slot),
                "slot {slot} appears twice in one decode batch"
            );
            let pos = kv.next_pos[slot];
            match kv.policy {
                KvPolicy::Exact => {
                    let span = (pos + 1).min(kv.window);
                    rows.push(forward::DecodeRow {
                        pos,
                        write: pos % kv.cap,
                        lo: pos + 1 - span,
                        hi: pos,
                    });
                }
                KvPolicy::Cur { .. } => {
                    let fill = kv.fill[slot];
                    ensure!(
                        fill < kv.cap,
                        "slot {slot} lane is full ({fill} rows) — run \
                         compress_kv_slot before the next decode step"
                    );
                    rows.push(forward::DecodeRow { pos, write: fill, lo: 0, hi: fill });
                }
            }
        }
        let dims = forward::layer_dims(cfg.n_heads, p, n, kv.cap, d)?;
        let (kc, vc) = (&mut kv.k[layer], &mut kv.v[layer]);
        let y = forward::layer_decode_impl(
            dims,
            p,
            x.f32s()?,
            kc.as_mut_slice(),
            vc.as_mut_slice(),
            slots,
            &rows,
            &mut sc,
        )?;
        if matches!(kv.policy, KvPolicy::Cur { .. }) {
            // Only after the kernel succeeded do the new rows' absolute
            // positions join this layer's maps — a failed step must not
            // leave them out of sync with `fill` (which the caller bumps
            // via `KvCache::advance` after the last layer).
            for (&slot, row) in slots.iter().zip(&rows) {
                kv.positions[layer][slot].push(row.pos);
            }
        }
        sc.rows = rows;
        Ok(Tensor::from_f32(&[n, 1, d], y))
    }

    fn compress_kv_slot(&self, _cfg: &ModelConfig, kv: &mut KvCache, slot: usize) -> Result<usize> {
        self.tick();
        let KvPolicy::Cur { keep, sinks, recent } = kv.policy else {
            bail!("compress_kv_slot needs a cur kv policy (cache policy is '{}')", kv.policy)
        };
        ensure!(slot < kv.b, "slot {slot} out of cache lanes 0..{}", kv.b);
        let (cap, d) = (kv.cap, kv.d);
        let fill = kv.fill[slot];
        ensure!(fill >= 2, "slot {slot} holds {fill} positions — nothing to compact");
        let lane = slot * cap * d;
        // Keep budget: `keep × window` positions, never fewer than the
        // protected set, and always at least one row freed.
        let target = ((keep as f64) * kv.window as f64).round() as usize;
        let mut retained_count = 0usize;
        for l in 0..kv.n_layers() {
            ensure!(
                kv.positions[l][slot].len() == fill,
                "slot {slot} layer {l} position map out of sync ({} vs fill {fill})",
                kv.positions[l][slot].len()
            );
            let retained: Vec<usize> = if keep >= 1.0 {
                // Degenerate exact sliding window: drop only the oldest
                // position (no sink protection — bit-identical to the
                // ring's eviction-by-overwrite).
                (1..fill).collect()
            } else {
                let pos = &kv.positions[l][slot];
                // Protected rows: attention sinks (absolute position
                // below `sinks`) and the newest `recent` rows. Both sets
                // hold the same positions in every layer — sinks are
                // never evicted once cached and the recent tail is the
                // same recent tokens — so every layer retains the same
                // count and `fill` stays one number per slot.
                let mut protected = vec![false; fill];
                for (i, &p) in pos.iter().enumerate() {
                    if p < sinks {
                        protected[i] = true;
                    }
                }
                for flag in protected.iter_mut().skip(fill.saturating_sub(recent)) {
                    *flag = true;
                }
                let free: Vec<usize> = (0..fill).filter(|&i| !protected[i]).collect();
                let n_prot = fill - free.len();
                let retain = target.clamp(n_prot.max(1).min(fill - 1), fill - 1);
                let budget = retain.saturating_sub(n_prot);
                let mut sel: Vec<usize> = (0..fill).filter(|&i| protected[i]).collect();
                if budget > 0 {
                    // budget <= free.len() - 1 by the clamp above, so
                    // selection always has real choices to make.
                    let kbuf = &kv.k[l][lane..lane + fill * d];
                    let vbuf = &kv.v[l][lane..lane + fill * d];
                    let mut keys = Mat::zeros(free.len(), d);
                    let mut weights = vec![0.0f64; free.len()];
                    for (fi, &i) in free.iter().enumerate() {
                        let kr = &kbuf[i * d..(i + 1) * d];
                        let vr = &vbuf[i * d..(i + 1) * d];
                        for (j, &x) in kr.iter().enumerate() {
                            keys[(fi, j)] = x as f64;
                        }
                        let kn: f64 = kr.iter().map(|&x| (x as f64) * (x as f64)).sum();
                        let vn: f64 = vr.iter().map(|&x| (x as f64) * (x as f64)).sum();
                        weights[fi] = (kn.sqrt() * vn.sqrt()).max(1e-12);
                    }
                    let picked = crate::cur::select_kv_positions(&keys, &weights, budget)?;
                    sel.extend(picked.into_iter().map(|fi| free[fi]));
                }
                sel.sort_unstable();
                sel
            };
            ensure!(
                retained_count == 0 || retained.len() == retained_count,
                "layers retained different position counts"
            );
            retained_count = retained.len();
            // Compact K, V and the position map to the lane prefix —
            // ascending physical order is ascending position order, so
            // the copy preserves the attention iteration order.
            let kl = &mut kv.k[l][lane..lane + cap * d];
            for (dst, &src) in retained.iter().enumerate() {
                if dst != src {
                    kl.copy_within(src * d..(src + 1) * d, dst * d);
                }
            }
            let vl = &mut kv.v[l][lane..lane + cap * d];
            for (dst, &src) in retained.iter().enumerate() {
                if dst != src {
                    vl.copy_within(src * d..(src + 1) * d, dst * d);
                }
            }
            let newpos: Vec<usize> = {
                let pos = &kv.positions[l][slot];
                retained.iter().map(|&i| pos[i]).collect()
            };
            kv.positions[l][slot] = newpos;
        }
        kv.fill[slot] = retained_count;
        kv.compactions += 1;
        Ok(fill - retained_count)
    }

    fn pack_head(&self, emb: &Tensor) -> Result<Option<crate::backend::PackedHead>> {
        ensure!(emb.shape.len() == 2, "emb must be (vocab, d), got {:?}", emb.shape);
        let (vocab, d) = (emb.shape[0], emb.shape[1]);
        Ok(Some(crate::backend::PackedHead {
            vocab,
            d,
            packed: math::pack_nt(emb.f32s()?, vocab, d),
        }))
    }

    fn head_logits_packed(
        &self,
        _cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        packed: &crate::backend::PackedHead,
    ) -> Result<Tensor> {
        self.tick();
        let (b, s, d) = Self::xdims(x)?;
        ensure!(packed.d == d, "packed head is d={}, hidden is d={d}", packed.d);
        let lnf = forward::want(ln_f, &[d], "ln_f")?;
        let rows = b * s;
        let mut xf = vec![0.0f32; rows * d];
        math::rmsnorm_into(x.f32s()?, lnf, rows, d, &mut xf);
        let mut logits = vec![0.0f32; rows * packed.vocab];
        math::matmul_nt_packed_into(&xf, &packed.packed, rows, &mut logits);
        Ok(Tensor::from_f32(&[b, s, packed.vocab], logits))
    }

    fn layer_forward_calib(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
    ) -> Result<CalibOut> {
        self.tick();
        let (b, s, d) = Self::xdims(x)?;
        let dims = forward::layer_dims(cfg.n_heads, p, b, s, d)?;
        let cache = forward::layer_forward_cached(dims, p, x.f32s()?)?;
        let colwise_sumsq = |m: &[f32]| -> Tensor {
            let mut acc = vec![0.0f32; d];
            for row in m.chunks_exact(d) {
                for (a, &v) in acc.iter_mut().zip(row) {
                    *a += v * v;
                }
            }
            Tensor::from_f32(&[d], acc)
        };
        Ok(CalibOut {
            attn_sumsq: colwise_sumsq(&cache.h1),
            ffn_sumsq: colwise_sumsq(&cache.h2),
            attn_in: Tensor::from_f32(&x.shape, cache.h1),
            ffn_in: Tensor::from_f32(&x.shape, cache.h2),
            y: Tensor::from_f32(&x.shape, cache.y),
        })
    }

    fn head_logits(
        &self,
        _cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        emb: &Tensor,
    ) -> Result<Tensor> {
        self.tick();
        let (b, s, d) = Self::xdims(x)?;
        ensure!(emb.shape.len() == 2 && emb.shape[1] == d, "emb must be (vocab, {d})");
        let vocab = emb.shape[0];
        let lnf = forward::want(ln_f, &[d], "ln_f")?;
        let (logits, _, _) = forward::head_forward(x.f32s()?, lnf, emb.f32s()?, b * s, d, vocab);
        Ok(Tensor::from_f32(&[b, s, vocab], logits))
    }

    fn head_nll(
        &self,
        _cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        emb: &Tensor,
        targets: &Tensor,
    ) -> Result<Tensor> {
        self.tick();
        let (b, s, d) = Self::xdims(x)?;
        ensure!(emb.shape.len() == 2 && emb.shape[1] == d, "emb must be (vocab, {d})");
        ensure!(targets.shape == [b, s], "targets must be ({b}, {s})");
        let vocab = emb.shape[0];
        let lnf = forward::want(ln_f, &[d], "ln_f")?;
        let (logits, _, _) = forward::head_forward(x.f32s()?, lnf, emb.f32s()?, b * s, d, vocab);
        let nll = forward::nll_rows(&logits, targets.i32s()?, b * s, vocab)?;
        Ok(Tensor::from_f32(&[b, s], nll))
    }

    fn train_step(
        &self,
        cfg: &ModelConfig,
        store: &mut TensorStore,
        opt: &mut TensorStore,
        tokens: &Tensor,
        targets: &Tensor,
        lr: f32,
        t: f32,
    ) -> Result<f64> {
        self.tick();
        train::train_step_impl(cfg, store, opt, tokens, targets, lr, t)
    }

    fn heal_step(
        &self,
        cfg: &ModelConfig,
        student: &mut TensorStore,
        opt: &mut TensorStore,
        layer: usize,
        x: &Tensor,
        y_teacher: &Tensor,
        lr: f32,
        t: f32,
    ) -> Result<HealOut> {
        self.tick();
        train::heal_step_impl(cfg, student, opt, layer, x, y_teacher, lr, t)
    }

    fn switched_step(
        &self,
        cfg: &ModelConfig,
        teacher: &TensorStore,
        student: &mut TensorStore,
        adapters: &mut TensorStore,
        opt: &mut TensorStore,
        adapter: crate::peft::Adapter,
        mode: StepMode,
        tokens: &Tensor,
        targets: &Tensor,
        loss_mask: Option<&Tensor>,
        lr: f32,
        t: f32,
    ) -> Result<f64> {
        self.tick();
        switched::switched_step_impl(
            cfg, teacher, student, adapters, opt, adapter, mode, tokens, targets, loss_mask,
            lr, t,
        )
    }

    fn switched_logits(
        &self,
        cfg: &ModelConfig,
        _teacher: &TensorStore,
        student: &TensorStore,
        adapters: &TensorStore,
        adapter: crate::peft::Adapter,
        tokens: &Tensor,
    ) -> Result<Tensor> {
        self.tick();
        switched::switched_logits_impl(cfg, student, adapters, adapter, tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Proj;
    use crate::util::Rng;
    use std::borrow::Cow;

    fn test_cfg(json: &str, name: &str) -> ModelConfig {
        ModelConfig::from_manifest(&Json::parse(json).unwrap(), name).unwrap()
    }

    fn small_cfg() -> ModelConfig {
        test_cfg(
            r#"{"configs":{"t":{"vocab":32,"d_model":16,"n_layers":2,"n_heads":2,
            "d_inter":24,"seq":8,"batch":2,"ranks":[4],"default_rank":4,
            "lora_rank":1,"mora_rank":4,"total_params":0}}}"#,
            "t",
        )
    }

    fn rand_t(rng: &mut Rng, shape: &[usize], std: f32) -> Tensor {
        Tensor::from_f32(shape, rng.normal_vec(shape.iter().product(), std))
    }

    /// Dense LayerParams over owned tensors (tests only).
    struct OwnedLayer {
        ln1: Tensor,
        ln2: Tensor,
        wq: Tensor,
        wk: Tensor,
        wv: Tensor,
        wo: Tensor,
        wgate: Tensor,
        wup: Tensor,
        wdown: Tensor,
    }

    impl OwnedLayer {
        fn random(rng: &mut Rng, d: usize, di: usize, std: f32) -> OwnedLayer {
            OwnedLayer {
                ln1: Tensor::from_f32(&[d], vec![1.0; d]),
                ln2: Tensor::from_f32(&[d], vec![1.0; d]),
                wq: rand_t(rng, &[d, d], std),
                wk: rand_t(rng, &[d, d], std),
                wv: rand_t(rng, &[d, d], std),
                wo: rand_t(rng, &[d, d], std),
                wgate: rand_t(rng, &[d, di], std),
                wup: rand_t(rng, &[d, di], std),
                wdown: rand_t(rng, &[di, d], std),
            }
        }

        fn params(&self) -> LayerParams<'_> {
            LayerParams {
                ln1: &self.ln1,
                ln2: &self.ln2,
                q: Proj::Dense(&self.wq),
                k: Proj::Dense(&self.wk),
                gate: Proj::Dense(&self.wgate),
                v: &self.wv,
                o: &self.wo,
                up: &self.wup,
                down: &self.wdown,
                adapter: None,
            }
        }
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol, "{what}[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn infer_forward_matches_cached_dense_and_cured() {
        // The inference-only path must reproduce the cached reference on
        // dense AND cured layers (same kernels, same per-row order), and
        // scratch reuse across calls must not corrupt outputs.
        let be = NativeBackend::new();
        let cfg = small_cfg();
        let (d, di) = (cfg.d_model, cfg.d_inter);
        let mut rng = Rng::new(41, 0);
        let layer = OwnedLayer::random(&mut rng, d, di, 0.2);
        let x = rand_t(&mut rng, &[2, 5, d], 1.0);
        let y_cached = be.layer_forward(&cfg, &layer.params(), &x).unwrap();
        let y_infer = be.layer_forward_infer(&cfg, &layer.params(), &x).unwrap();
        assert_close(
            y_cached.f32s().unwrap(),
            y_infer.f32s().unwrap(),
            1e-6,
            "dense infer",
        );
        // Second call through the (now-warm) scratch.
        let y_again = be.layer_forward_infer(&cfg, &layer.params(), &x).unwrap();
        assert_eq!(y_infer, y_again, "scratch reuse must be deterministic");
        // Cured q projection.
        let r = 4usize;
        let c = rand_t(&mut rng, &[d, r], 0.4);
        let u = rand_t(&mut rng, &[r, r], 0.4);
        let rr = rand_t(&mut rng, &[r, d], 0.4);
        let mut p = layer.params();
        p.q = Proj::Cured { c: &c, u: Cow::Borrowed(&u), r: &rr };
        let y_cached = be.layer_forward(&cfg, &p, &x).unwrap();
        let y_infer = be.layer_forward_infer(&cfg, &p, &x).unwrap();
        assert_close(
            y_cached.f32s().unwrap(),
            y_infer.f32s().unwrap(),
            1e-6,
            "cured infer",
        );
    }

    #[test]
    fn prefill_and_decode_match_full_forward() {
        // Per-slot prefill over the first 5 positions + one fused decode
        // step at position 5 must equal the full 6-token forward:
        // prefill rows bit-match by causality, and the decoded rows
        // match position 5 across both slots.
        let be = NativeBackend::new();
        let cfg = small_cfg();
        let (d, di) = (cfg.d_model, cfg.d_inter);
        let (b, s) = (2usize, 6usize);
        let mut rng = Rng::new(42, 0);
        let layer = OwnedLayer::random(&mut rng, d, di, 0.2);
        let x_full = rand_t(&mut rng, &[b, s, d], 1.0);
        let y_full = be.layer_forward_infer(&cfg, &layer.params(), &x_full).unwrap();
        let yf = y_full.f32s().unwrap();
        let mut kv = crate::backend::KvCache::new(1, b, s, d);
        for slot in 0..b {
            // This slot's first s-1 rows as a (1, s-1, d) prompt window.
            let w = s - 1;
            let rows =
                x_full.f32s().unwrap()[(slot * s) * d..(slot * s + w) * d].to_vec();
            let x_pre = Tensor::from_f32(&[1, w, d], rows);
            let y_pre =
                be.layer_prefill(&cfg, &layer.params(), &x_pre, &mut kv, 0, slot).unwrap();
            kv.commit_prefill(slot, w);
            // Causality: prefill rows agree with the full forward.
            let yp = y_pre.f32s().unwrap();
            for pos in 0..w {
                let o = (slot * s + pos) * d;
                assert_close(&yf[o..o + d], &yp[pos * d..(pos + 1) * d], 1e-6, "prefill row");
            }
        }
        // Decode the final position of both slots in one fused call.
        let mut x_new = vec![0.0f32; b * d];
        for bi in 0..b {
            x_new[bi * d..(bi + 1) * d]
                .copy_from_slice(&x_full.f32s().unwrap()[(bi * s + s - 1) * d..(bi * s + s) * d]);
        }
        let x_new = Tensor::from_f32(&[b, 1, d], x_new);
        let y_dec = be
            .layer_decode_batch(&cfg, &layer.params(), &x_new, &mut kv, 0, &[0, 1])
            .unwrap();
        let yd = y_dec.f32s().unwrap();
        for bi in 0..b {
            let o = (bi * s + s - 1) * d;
            assert_close(&yf[o..o + d], &yd[bi * d..(bi + 1) * d], 1e-6, "decode row");
        }
        // The cache footprint accounting is honest.
        assert_eq!(kv.bytes(), 2 * b * s * d * 4);
    }

    #[test]
    fn ring_rotation_matches_linear_cache_bitwise() {
        // The rotation invariant: feeding T > cap tokens through a
        // wrapping ring (cap == window) must produce bit-identical
        // outputs to the same stream through a never-wrapping linear
        // cache (cap == T) with the same attention window — eviction by
        // overwrite IS the sliding window, no recompute anywhere. Also
        // runs the ring side as a 2-slot fused batch against the linear
        // side's single-slot calls, pinning slot-fusion independence.
        let be = NativeBackend::new();
        let cfg = small_cfg();
        let (d, di) = (cfg.d_model, cfg.d_inter);
        let (window, t_total) = (4usize, 7usize);
        let mut rng = Rng::new(43, 0);
        let layer = OwnedLayer::random(&mut rng, d, di, 0.2);
        let xs: Vec<Tensor> = (0..t_total).map(|_| rand_t(&mut rng, &[1, 1, d], 1.0)).collect();
        // Ring: two slots fed the same stream, fused per step.
        let mut ring = crate::backend::KvCache::new(1, 2, window, d);
        // Linear: one slot, capacity covers the whole stream.
        let mut lin = crate::backend::KvCache::with_capacity(1, 1, window, t_total, d);
        for x in &xs {
            let mut both = x.f32s().unwrap().to_vec();
            both.extend_from_slice(x.f32s().unwrap());
            let xb = Tensor::from_f32(&[2, 1, d], both);
            let y_ring =
                be.layer_decode_batch(&cfg, &layer.params(), &xb, &mut ring, 0, &[0, 1]).unwrap();
            ring.advance(&[0, 1]);
            let y_lin =
                be.layer_decode_batch(&cfg, &layer.params(), x, &mut lin, 0, &[0]).unwrap();
            lin.advance(&[0]);
            let (yr, yl) = (y_ring.f32s().unwrap(), y_lin.f32s().unwrap());
            assert_eq!(&yr[..d], yl, "ring slot 0 diverged from linear cache");
            assert_eq!(&yr[d..], yl, "ring slot 1 diverged from linear cache");
        }
        assert_eq!(ring.next_pos, vec![t_total; 2]);
    }

    #[test]
    fn compacted_lane_keep_one_matches_ring_bitwise() {
        // Feeding T > window tokens through a Cur{keep: 1.0} compacted
        // lane (compact-on-full, drop-oldest) must produce bit-identical
        // layer outputs to the exact ring: the lane machinery (append
        // writes, compaction row moves, flat ascending attention) is
        // pure bookkeeping, so every keep < 1 divergence is an eviction
        // *choice*, never numeric drift.
        let be = NativeBackend::new();
        let cfg = small_cfg();
        let (d, di) = (cfg.d_model, cfg.d_inter);
        let (window, t_total) = (4usize, 9usize);
        let mut rng = Rng::new(44, 0);
        let layer = OwnedLayer::random(&mut rng, d, di, 0.2);
        let xs: Vec<Tensor> =
            (0..t_total).map(|_| rand_t(&mut rng, &[1, 1, d], 1.0)).collect();
        let mut ring = crate::backend::KvCache::new(1, 1, window, d);
        let policy = crate::backend::KvPolicy::Cur { keep: 1.0, sinks: 1, recent: 1 };
        let mut lane = crate::backend::KvCache::with_policy(1, 1, window, d, policy);
        for x in &xs {
            if lane.needs_compaction(0) {
                be.compress_kv_slot(&cfg, &mut lane, 0).unwrap();
            }
            let y_ring = be
                .layer_decode_batch(&cfg, &layer.params(), x, &mut ring, 0, &[0])
                .unwrap();
            ring.advance(&[0]);
            let y_lane = be
                .layer_decode_batch(&cfg, &layer.params(), x, &mut lane, 0, &[0])
                .unwrap();
            lane.advance(&[0]);
            assert_eq!(y_ring, y_lane, "compacted lane diverged from the exact ring");
        }
        assert!(lane.compactions > 0, "the lane never compacted");
        assert_eq!(lane.next_pos[0], t_total);
    }

    #[test]
    fn compress_kv_slot_moves_rows_intact() {
        // Compaction must relocate whole K/V rows (values untouched),
        // keep the maps ascending, and honor sink + recent protection.
        let be = NativeBackend::new();
        let cfg = small_cfg();
        let d = cfg.d_model;
        let window = 8usize;
        let policy = crate::backend::KvPolicy::Cur { keep: 0.5, sinks: 1, recent: 2 };
        let mut kv = crate::backend::KvCache::with_policy(2, 1, window, d, policy);
        // Hand-fill the lane: row r of layer l holds the constant
        // l·100 + r, so provenance is readable after the move.
        for l in 0..2 {
            for r in 0..window {
                for j in 0..d {
                    kv.k[l][r * d + j] = (l * 100 + r) as f32;
                    kv.v[l][r * d + j] = (l * 100 + r) as f32 + 0.5;
                }
            }
            kv.positions[l][0] = (0..window).collect();
        }
        kv.fill[0] = window;
        kv.next_pos[0] = window;
        let dropped = be.compress_kv_slot(&cfg, &mut kv, 0).unwrap();
        assert_eq!(kv.fill[0], 4, "keep 0.5 of an 8-row window retains 4");
        assert_eq!(dropped, 4);
        assert_eq!(kv.compactions, 1);
        for l in 0..2 {
            let map = &kv.positions[l][0];
            assert_eq!(map.len(), 4);
            assert!(map.windows(2).all(|w| w[0] < w[1]), "map must stay ascending");
            assert_eq!(map[0], 0, "the sink position must survive");
            assert_eq!(map[2..].to_vec(), vec![6, 7], "the recent tail must survive");
            for (row, &p) in map.iter().enumerate() {
                assert_eq!(kv.k[l][row * d], (l * 100 + p) as f32, "layer {l} K row moved wrong");
                assert_eq!(kv.v[l][row * d], (l * 100 + p) as f32 + 0.5, "layer {l} V row moved wrong");
            }
        }
        // An exact-policy cache refuses compaction outright.
        let mut exact = crate::backend::KvCache::new(1, 1, window, d);
        exact.next_pos[0] = window;
        assert!(be.compress_kv_slot(&cfg, &mut exact, 0).is_err());
    }

    #[test]
    fn embed_gathers_rows() {
        let be = NativeBackend::new();
        let cfg = small_cfg();
        let mut rng = Rng::new(1, 0);
        let emb = rand_t(&mut rng, &[cfg.vocab, cfg.d_model], 1.0);
        let tokens = Tensor::from_i32(&[1, 3], vec![5, 0, 31]);
        let x = be.embed(&cfg, &emb, &tokens).unwrap();
        assert_eq!(x.shape, vec![1, 3, cfg.d_model]);
        let e = emb.f32s().unwrap();
        let xs = x.f32s().unwrap();
        let d = cfg.d_model;
        assert_eq!(&xs[..d], &e[5 * d..6 * d]);
        assert_eq!(&xs[d..2 * d], &e[..d]);
        // Out-of-vocab token is an error, not UB.
        let bad = Tensor::from_i32(&[1, 1], vec![32]);
        assert!(be.embed(&cfg, &emb, &bad).is_err());
    }

    #[test]
    fn layer_forward_is_finite_and_causal() {
        let be = NativeBackend::new();
        let cfg = small_cfg();
        let (d, di) = (cfg.d_model, cfg.d_inter);
        let mut rng = Rng::new(2, 0);
        let layer = OwnedLayer::random(&mut rng, d, di, 0.2);
        let x = rand_t(&mut rng, &[1, 4, d], 1.0);
        let y = be.layer_forward(&cfg, &layer.params(), &x).unwrap();
        assert_eq!(y.shape, x.shape);
        assert!(y.f32s().unwrap().iter().all(|v| v.is_finite()));
        // Causality: changing a later token must not affect earlier outputs.
        let mut x2 = x.clone();
        {
            let xs = x2.f32s_mut().unwrap();
            for j in 0..d {
                xs[3 * d + j] += 1.0;
            }
        }
        let y2 = be.layer_forward(&cfg, &layer.params(), &x2).unwrap();
        let (a, b) = (y.f32s().unwrap(), y2.f32s().unwrap());
        for i in 0..3 * d {
            assert!((a[i] - b[i]).abs() < 1e-6, "position {} leaked", i / d);
        }
        assert!((0..d).any(|j| (a[3 * d + j] - b[3 * d + j]).abs() > 1e-4));
    }

    #[test]
    fn cured_chain_matches_equivalent_dense() {
        // A cured projection with C·U·R == W must produce the same layer
        // output as the dense weight.
        let be = NativeBackend::new();
        let cfg = small_cfg();
        let (d, di) = (cfg.d_model, cfg.d_inter);
        let mut rng = Rng::new(3, 0);
        let mut layer = OwnedLayer::random(&mut rng, d, di, 0.2);
        let r = 4usize;
        let c = rand_t(&mut rng, &[d, r], 0.4);
        let u = rand_t(&mut rng, &[r, r], 0.4);
        let rr = rand_t(&mut rng, &[r, d], 0.4);
        // Dense equivalent W = C·U·R.
        let cu = math::matmul_nn(c.f32s().unwrap(), u.f32s().unwrap(), d, r, r);
        let w = math::matmul_nn(&cu, rr.f32s().unwrap(), d, r, d);
        layer.wq = Tensor::from_f32(&[d, d], w);
        let x = rand_t(&mut rng, &[2, 4, d], 1.0);
        let y_dense = be.layer_forward(&cfg, &layer.params(), &x).unwrap();
        let mut p = layer.params();
        p.q = Proj::Cured { c: &c, u: Cow::Borrowed(&u), r: &rr };
        let y_cur = be.layer_forward(&cfg, &p, &x).unwrap();
        let (a, b) = (y_dense.f32s().unwrap(), y_cur.f32s().unwrap());
        for (x1, x2) in a.iter().zip(b) {
            assert!((x1 - x2).abs() < 1e-3, "{x1} vs {x2}");
        }
    }

    #[test]
    fn calib_taps_are_consistent() {
        let be = NativeBackend::new();
        let cfg = small_cfg();
        let mut rng = Rng::new(4, 0);
        let layer = OwnedLayer::random(&mut rng, cfg.d_model, cfg.d_inter, 0.2);
        let x = rand_t(&mut rng, &[2, 5, cfg.d_model], 1.0);
        let y = be.layer_forward(&cfg, &layer.params(), &x).unwrap();
        let calib = be.layer_forward_calib(&cfg, &layer.params(), &x).unwrap();
        assert_eq!(calib.y, y, "calib forward must match the plain forward");
        // Σx² taps must equal the column-wise sum of squares of the taps'
        // own raw inputs.
        let d = cfg.d_model;
        for (sumsq, raw) in [(&calib.attn_sumsq, &calib.attn_in), (&calib.ffn_sumsq, &calib.ffn_in)]
        {
            assert_eq!(sumsq.shape, vec![d]);
            assert_eq!(raw.shape, x.shape);
            let rawf = raw.f32s().unwrap();
            for j in 0..d {
                let want: f32 = rawf.chunks_exact(d).map(|row| row[j] * row[j]).sum();
                let got = sumsq.f32s().unwrap()[j];
                assert!((want - got).abs() < 1e-3 * (1.0 + want.abs()));
            }
        }
    }

    #[test]
    fn head_nll_matches_logits_softmax() {
        let be = NativeBackend::new();
        let cfg = small_cfg();
        let (d, v) = (cfg.d_model, cfg.vocab);
        let mut rng = Rng::new(5, 0);
        let emb = rand_t(&mut rng, &[v, d], 0.5);
        let ln_f = Tensor::from_f32(&[d], vec![1.0; d]);
        let x = rand_t(&mut rng, &[1, 3, d], 1.0);
        let targets = Tensor::from_i32(&[1, 3], vec![7, 0, 31]);
        let logits = be.head_logits(&cfg, &x, &ln_f, &emb).unwrap();
        let nll = be.head_nll(&cfg, &x, &ln_f, &emb, &targets).unwrap();
        assert_eq!(logits.shape, vec![1, 3, v]);
        assert_eq!(nll.shape, vec![1, 3]);
        let lf = logits.f32s().unwrap();
        let tg = targets.i32s().unwrap();
        for r in 0..3 {
            let row = &lf[r * v..(r + 1) * v];
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let logz = maxv as f64
                + row.iter().map(|&z| ((z - maxv) as f64).exp()).sum::<f64>().ln();
            let want = logz - row[tg[r] as usize] as f64;
            let got = nll.f32s().unwrap()[r] as f64;
            assert!((want - got).abs() < 1e-4, "{want} vs {got}");
        }
        // Out-of-range target errors gracefully.
        let bad = Tensor::from_i32(&[1, 3], vec![7, 0, 32]);
        assert!(be.head_nll(&cfg, &x, &ln_f, &emb, &bad).is_err());
    }

    #[test]
    fn dense_layer_gradients_match_finite_difference() {
        // Scalar probe loss L = Σ c ⊙ layer(x): checks backprop through
        // attention, RoPE, both RMSNorms and the SwiGLU FFN.
        let cfg = small_cfg();
        let (d, di, nh) = (cfg.d_model, cfg.d_inter, cfg.n_heads);
        let (b, s) = (1usize, 4usize);
        let mut rng = Rng::new(6, 0);
        let layer = OwnedLayer::random(&mut rng, d, di, 0.3);
        let x = rng.normal_vec(b * s * d, 1.0);
        let c = rng.normal_vec(b * s * d, 1.0);
        let loss_of = |layer: &OwnedLayer, x: &[f32]| -> f64 {
            let p = layer.params();
            let dims = forward::layer_dims(nh, &p, b, s, d).unwrap();
            let cache = forward::layer_forward_cached(dims, &p, x).unwrap();
            cache.y.iter().zip(&c).map(|(&a, &w)| (a as f64) * (w as f64)).sum()
        };
        // Analytic grads.
        let p = layer.params();
        let dims = forward::layer_dims(nh, &p, b, s, d).unwrap();
        let cache = forward::layer_forward_cached(dims, &p, &x).unwrap();
        let g = train::layer_backward(&p, &x, &cache, &c).unwrap();
        drop(p);
        let eps = 3e-3f32;
        let check = |name: &str, analytic: f32, numeric: f64| {
            assert!(
                (numeric - analytic as f64).abs() < 0.05 * (1.0 + numeric.abs()),
                "{name}: analytic {analytic} vs numeric {numeric}"
            );
        };
        // dx
        for &i in &[0usize, 17, 40, 63] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (loss_of(&layer, &xp) - loss_of(&layer, &xm)) / (2.0 * eps as f64);
            check("dx", g.dx[i], num);
        }
        // A few weight entries per matrix.
        let probe = |field: fn(&mut OwnedLayer) -> &mut Tensor,
                     grad: &[f32],
                     idx: usize,
                     name: &str| {
            let mut lp = OwnedLayer {
                ln1: layer.ln1.clone(),
                ln2: layer.ln2.clone(),
                wq: layer.wq.clone(),
                wk: layer.wk.clone(),
                wv: layer.wv.clone(),
                wo: layer.wo.clone(),
                wgate: layer.wgate.clone(),
                wup: layer.wup.clone(),
                wdown: layer.wdown.clone(),
            };
            field(&mut lp).f32s_mut().unwrap()[idx] += eps;
            let up = loss_of(&lp, &x);
            field(&mut lp).f32s_mut().unwrap()[idx] -= 2.0 * eps;
            let down = loss_of(&lp, &x);
            let num = (up - down) / (2.0 * eps as f64);
            check(name, grad[idx], num);
        };
        let gq = match &g.q {
            train::ProjGrad::Dense(v) => v.clone(),
            _ => unreachable!(),
        };
        let gk = match &g.k {
            train::ProjGrad::Dense(v) => v.clone(),
            _ => unreachable!(),
        };
        let gg = match &g.gate {
            train::ProjGrad::Dense(v) => v.clone(),
            _ => unreachable!(),
        };
        probe(|l| &mut l.wq, &gq, 5, "dWq");
        probe(|l| &mut l.wk, &gk, 33, "dWk");
        probe(|l| &mut l.wv, &g.v, 70, "dWv");
        probe(|l| &mut l.wo, &g.o, 128, "dWo");
        probe(|l| &mut l.wgate, &gg, 11, "dWgate");
        probe(|l| &mut l.wup, &g.up, 200, "dWup");
        probe(|l| &mut l.wdown, &g.down, 90, "dWdown");
        probe(|l| &mut l.ln1, &g.ln1, 3, "dln1");
        probe(|l| &mut l.ln2, &g.ln2, 9, "dln2");
    }

    #[test]
    fn cured_du_gradient_matches_finite_difference() {
        let cfg = small_cfg();
        let (d, di, nh) = (cfg.d_model, cfg.d_inter, cfg.n_heads);
        let (b, s) = (1usize, 4usize);
        let mut rng = Rng::new(7, 0);
        let layer = OwnedLayer::random(&mut rng, d, di, 0.3);
        let r = 4usize;
        let c_q = rand_t(&mut rng, &[d, r], 0.4);
        let u_q = rand_t(&mut rng, &[r, r], 0.4);
        let r_q = rand_t(&mut rng, &[r, d], 0.4);
        let x = rng.normal_vec(b * s * d, 1.0);
        let yt = rng.normal_vec(b * s * d, 1.0);
        let loss_of = |u: &Tensor| -> f64 {
            let mut p = layer.params();
            p.q = Proj::Cured { c: &c_q, u: Cow::Borrowed(u), r: &r_q };
            let (loss, _, _) = train::heal_grads(nh, &p, b, s, d, &x, &yt).unwrap();
            loss
        };
        let mut p = layer.params();
        p.q = Proj::Cured { c: &c_q, u: Cow::Borrowed(&u_q), r: &r_q };
        let (_, _, dus) = train::heal_grads(nh, &p, b, s, d, &x, &yt).unwrap();
        drop(p);
        assert_eq!(dus.len(), 1);
        assert_eq!(dus[0].0, "q");
        let du = &dus[0].1;
        let eps = 1e-2f32;
        for &i in &[0usize, 5, 10, 15] {
            let mut up = u_q.clone();
            up.f32s_mut().unwrap()[i] += eps;
            let mut dn = u_q.clone();
            dn.f32s_mut().unwrap()[i] -= eps;
            let num = (loss_of(&up) - loss_of(&dn)) / (2.0 * eps as f64);
            assert!(
                (num - du[i] as f64).abs() < 0.05 * (1.0 + num.abs()) + 1e-4,
                "dU[{i}]: analytic {} vs numeric {num}",
                du[i]
            );
        }
    }

    #[test]
    fn train_step_memorizes_a_fixed_batch() {
        let cfg = small_cfg();
        let mut rng = Rng::new(8, 0);
        let mut store = cfg.init_dense(&mut rng);
        let mut opt = TensorStore::new();
        let be = NativeBackend::new();
        let (b, s) = (cfg.batch, cfg.seq);
        let toks: Vec<i32> = (0..b * s).map(|_| rng.below(cfg.vocab) as i32).collect();
        let mut tgts = toks[1..].to_vec();
        tgts.push(0);
        let tokens = Tensor::from_i32(&[b, s], toks);
        let targets = Tensor::from_i32(&[b, s], tgts);
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..30 {
            let loss = be
                .train_step(&cfg, &mut store, &mut opt, &tokens, &targets, 3e-3, (step + 1) as f32)
                .unwrap();
            assert!(loss.is_finite(), "step {step} loss {loss}");
            if step == 0 {
                first = loss;
            }
            last = loss;
        }
        assert!(
            last < first * 0.7,
            "training on a fixed batch must reduce loss: first {first} last {last}"
        );
        // Optimizer state exists for every parameter.
        for n in cfg.dense_param_names() {
            assert!(opt.contains(&format!("m.{n}")), "missing m.{n}");
            assert!(opt.contains(&format!("v.{n}")), "missing v.{n}");
        }
    }
}

//! Forward passes of the native backend: the Llama-mini transformer
//! layer (RMSNorm → RoPE causal attention → RMSNorm → SwiGLU FFN, both
//! with residuals), dense or CUR-factored q/k/gate chains, and the tied
//! LM head. Every forward caches the intermediates the backward pass
//! (train/heal steps) consumes — at coordinator scale the caches are a
//! few MiB and recomputation would dominate the step cost.

use super::math::{
    add_inplace, matmul_nn, matmul_nt, rmsnorm_fwd, rope_apply, rope_table, silu,
};
use crate::backend::{LayerParams, Proj};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Problem dimensions of one layer call.
#[derive(Debug, Clone, Copy)]
pub(super) struct Dims {
    pub b: usize,
    pub s: usize,
    pub d: usize,
    pub di: usize,
    pub nh: usize,
    pub dh: usize,
}

pub(super) fn want<'a>(t: &'a Tensor, shape: &[usize], what: &str) -> Result<&'a [f32]> {
    ensure!(
        t.shape.as_slice() == shape,
        "{what}: expected shape {shape:?}, got {:?}",
        t.shape
    );
    t.f32s()
}

/// (in_dim, out_dim) of a projection, with full shape validation.
pub(super) fn proj_dims(p: &Proj, what: &str) -> Result<(usize, usize)> {
    match p {
        Proj::Dense(w) => {
            ensure!(w.shape.len() == 2, "{what}: dense weight must be rank 2");
            Ok((w.shape[0], w.shape[1]))
        }
        Proj::Cured { c, u, r } => {
            ensure!(
                c.shape.len() == 2 && u.shape.len() == 2 && r.shape.len() == 2,
                "{what}: CUR factors must be rank 2"
            );
            let rank = c.shape[1];
            ensure!(
                u.shape == [rank, rank] && r.shape[0] == rank,
                "{what}: inconsistent CUR ranks (C {:?}, U {:?}, R {:?})",
                c.shape,
                u.shape,
                r.shape
            );
            Ok((c.shape[0], r.shape[1]))
        }
    }
}

/// Cached intermediates of a cured projection chain.
pub(super) struct ProjCache {
    /// h·C, (rows × r).
    pub hc: Vec<f32>,
    /// (h·C)·U, (rows × r).
    pub hcu: Vec<f32>,
}

/// Projection forward: returns the output plus the chain cache when cured.
pub(super) fn proj_forward(
    h: &[f32],
    rows: usize,
    p: &Proj,
    what: &str,
) -> Result<(Vec<f32>, Option<ProjCache>)> {
    let (m, n) = proj_dims(p, what)?;
    ensure!(h.len() == rows * m, "{what}: input is not rows×{m}");
    match p {
        Proj::Dense(w) => Ok((matmul_nn(h, w.f32s()?, rows, m, n), None)),
        Proj::Cured { c, u, r } => {
            let rank = c.shape[1];
            let hc = matmul_nn(h, c.f32s()?, rows, m, rank);
            let hcu = matmul_nn(&hc, u.f32s()?, rows, rank, rank);
            let out = matmul_nn(&hcu, r.f32s()?, rows, rank, n);
            Ok((out, Some(ProjCache { hc, hcu })))
        }
    }
}

/// Everything one layer forward produces, kept for the backward pass.
pub(super) struct LayerCache {
    pub dims: Dims,
    /// Post-ln1 attention input, (bs × d).
    pub h1: Vec<f32>,
    pub inv1: Vec<f32>,
    /// q/k post-RoPE, v; all (bs × d).
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Causal softmax probabilities, (b·nh·s·s).
    pub probs: Vec<f32>,
    /// Concatenated head outputs before the o-projection, (bs × d).
    pub att: Vec<f32>,
    /// Post-attention residual stream, (bs × d).
    pub x2: Vec<f32>,
    pub inv2: Vec<f32>,
    /// Post-ln2 FFN input, (bs × d).
    pub h2: Vec<f32>,
    /// Gate pre-activation (bs × di), up branch, silu(g)⊙up.
    pub g: Vec<f32>,
    pub up: Vec<f32>,
    pub act: Vec<f32>,
    /// Layer output, (bs × d).
    pub y: Vec<f32>,
    pub qc: Option<ProjCache>,
    pub kc: Option<ProjCache>,
    pub gc: Option<ProjCache>,
}

pub(super) fn layer_dims(
    n_heads: usize,
    p: &LayerParams,
    b: usize,
    s: usize,
    d: usize,
) -> Result<Dims> {
    ensure!(n_heads > 0 && d % n_heads == 0, "d_model {d} not divisible by {n_heads} heads");
    let dh = d / n_heads;
    ensure!(dh % 2 == 0, "head dim {dh} must be even for RoPE");
    let (qi, qo) = proj_dims(&p.q, "w_q")?;
    let (ki, ko) = proj_dims(&p.k, "w_k")?;
    ensure!(qi == d && qo == d && ki == d && ko == d, "q/k projections must be {d}×{d}");
    let (gi, di) = proj_dims(&p.gate, "w_gate")?;
    ensure!(gi == d, "gate projection input dim {gi} != {d}");
    ensure!(p.up.shape == [d, di], "w_up must be {d}×{di}, got {:?}", p.up.shape);
    ensure!(p.down.shape == [di, d], "w_down must be {di}×{d}, got {:?}", p.down.shape);
    Ok(Dims { b, s, d, di, nh: n_heads, dh })
}

/// Causal multi-head attention forward; returns (softmax probs, concat
/// head outputs). Single-threaded: at coordinator scale the projections
/// around it dominate.
pub(super) fn attention_fwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dims: Dims,
) -> (Vec<f32>, Vec<f32>) {
    let Dims { b, s, d, nh, dh, .. } = dims;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut probs = vec![0.0f32; b * nh * s * s];
    let mut att = vec![0.0f32; b * s * d];
    for bi in 0..b {
        for h in 0..nh {
            let pbase = (bi * nh + h) * s * s;
            for si in 0..s {
                let qoff = (bi * s + si) * d + h * dh;
                let qrow = &q[qoff..qoff + dh];
                let prow = &mut probs[pbase + si * s..pbase + (si + 1) * s];
                let mut maxv = f32::NEG_INFINITY;
                for sj in 0..=si {
                    let koff = (bi * s + sj) * d + h * dh;
                    let krow = &k[koff..koff + dh];
                    let mut dot = 0.0f32;
                    for (a, b2) in qrow.iter().zip(krow) {
                        dot += a * b2;
                    }
                    let sc = dot * scale;
                    prow[sj] = sc;
                    if sc > maxv {
                        maxv = sc;
                    }
                }
                let mut sum = 0.0f32;
                for p in prow.iter_mut().take(si + 1) {
                    *p = (*p - maxv).exp();
                    sum += *p;
                }
                let isum = 1.0 / sum;
                for sj in 0..=si {
                    prow[sj] *= isum;
                    let voff = (bi * s + sj) * d + h * dh;
                    let vrow = &v[voff..voff + dh];
                    let aoff = (bi * s + si) * d + h * dh;
                    let pval = prow[sj];
                    for (jj, &vv) in vrow.iter().enumerate() {
                        att[aoff + jj] += pval * vv;
                    }
                }
            }
        }
    }
    (probs, att)
}

/// Full layer forward with caches. `x` is the flat (bs × d) input.
pub(super) fn layer_forward_cached(
    dims: Dims,
    p: &LayerParams,
    x: &[f32],
) -> Result<LayerCache> {
    let Dims { b, s, d, di, nh, dh } = dims;
    let bs = b * s;
    ensure!(x.len() == bs * d, "layer input length mismatch");
    let ln1 = want(p.ln1, &[d], "ln1")?;
    let ln2 = want(p.ln2, &[d], "ln2")?;
    let wv = want(p.v, &[d, d], "w_v")?;
    let wo = want(p.o, &[d, d], "w_o")?;
    let wup = want(p.up, &[d, di], "w_up")?;
    let wdown = want(p.down, &[di, d], "w_down")?;

    let (h1, inv1) = rmsnorm_fwd(x, ln1, bs, d);
    let (mut q, qc) = proj_forward(&h1, bs, &p.q, "w_q")?;
    let (mut k, kc) = proj_forward(&h1, bs, &p.k, "w_k")?;
    let v = matmul_nn(&h1, wv, bs, d, d);
    let (cos, sin) = rope_table(s, dh / 2);
    rope_apply(&mut q, b, s, nh, dh, &cos, &sin, 1.0);
    rope_apply(&mut k, b, s, nh, dh, &cos, &sin, 1.0);
    let (probs, att) = attention_fwd(&q, &k, &v, dims);
    let mut x2 = matmul_nn(&att, wo, bs, d, d);
    add_inplace(&mut x2, x);

    let (h2, inv2) = rmsnorm_fwd(&x2, ln2, bs, d);
    let (g, gc) = proj_forward(&h2, bs, &p.gate, "w_gate")?;
    let up = matmul_nn(&h2, wup, bs, d, di);
    let mut act = vec![0.0f32; bs * di];
    for i in 0..bs * di {
        act[i] = silu(g[i]) * up[i];
    }
    let mut y = matmul_nn(&act, wdown, bs, di, d);
    add_inplace(&mut y, &x2);

    Ok(LayerCache {
        dims,
        h1,
        inv1,
        q,
        k,
        v,
        probs,
        att,
        x2,
        inv2,
        h2,
        g,
        up,
        act,
        y,
        qc,
        kc,
        gc,
    })
}

/// Head forward: final RMSNorm then tied-embedding logits. Returns
/// (logits (rows × vocab), xf (rows × d), per-row inverse RMS).
pub(super) fn head_forward(
    x: &[f32],
    ln_f: &[f32],
    emb: &[f32],
    rows: usize,
    d: usize,
    vocab: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (xf, inv) = rmsnorm_fwd(x, ln_f, rows, d);
    let logits = matmul_nt(&xf, emb, rows, d, vocab);
    (logits, xf, inv)
}

/// Per-row negative log-likelihood from logits.
pub(super) fn nll_rows(logits: &[f32], targets: &[i32], rows: usize, vocab: usize) -> Result<Vec<f32>> {
    ensure!(targets.len() == rows, "targets length mismatch");
    let mut out = vec![0.0f32; rows];
    for r in 0..rows {
        let t = targets[r];
        ensure!(
            (0..vocab as i32).contains(&t),
            "target token {t} out of vocab range 0..{vocab}"
        );
        let row = &logits[r * vocab..(r + 1) * vocab];
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let logz =
            maxv as f64 + row.iter().map(|&x| ((x - maxv) as f64).exp()).sum::<f64>().ln();
        out[r] = (logz - row[t as usize] as f64) as f32;
    }
    Ok(out)
}

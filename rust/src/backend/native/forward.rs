//! Forward passes of the native backend.
//!
//! Two execution paths share the Llama-mini layer math (RMSNorm → RoPE
//! causal attention → RMSNorm → SwiGLU FFN, both with residuals, dense or
//! CUR-factored q/k/gate chains):
//!
//! * [`layer_forward_cached`] — the train/heal path. Caches every
//!   intermediate the backward pass consumes (softmax probs + ~10
//!   activation buffers per layer).
//! * [`layer_infer_impl`] / [`layer_decode_impl`] — the inference path.
//!   No backward caches: a small [`InferScratch`] buffer set is reused
//!   across layer calls, attention never materializes the (b·nh·s·s)
//!   probability tensor, and RoPE tables come from the process-wide
//!   cache. `layer_infer_impl` optionally captures post-RoPE K/V into a
//!   KV cache lane (per-slot prefill); `layer_decode_impl` advances one
//!   position per active slot against ring-buffer K/V, fusing N slots
//!   into one batched layer pass.
//!
//! Both paths drive the same kernels in the same per-row accumulation
//! order, so they agree bit-for-bit — the parity tests assert it.

use super::math::{
    add_inplace, dot, matmul_nn, matmul_nn_into, matmul_nt, par_chunk_tasks, par_pair_tasks,
    rmsnorm_fwd, rmsnorm_into, rope_apply, rope_apply_rows_local, rope_row_into,
    rope_tables_cached, silu,
};
use crate::backend::{LayerParams, Proj, ProjAdapter};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// MoRA's parameter-free compression group size: input features are
/// summed in contiguous groups of `ceil(dim/rank)` (and outputs
/// broadcast the same way), so `rank` groups always cover `dim`.
pub(super) fn mora_group(dim: usize, rank: usize) -> usize {
    dim.div_ceil(rank)
}

/// MoRA compress: (rows × m) → (rows × rank) by contiguous group sums.
fn mora_compress(x: &[f32], rows: usize, m: usize, rank: usize, out: &mut [f32]) {
    let gi = mora_group(m, rank);
    out.fill(0.0);
    for r in 0..rows {
        let xr = &x[r * m..(r + 1) * m];
        let or = &mut out[r * rank..(r + 1) * rank];
        for (i, &v) in xr.iter().enumerate() {
            or[i / gi] += v;
        }
    }
}

/// Problem dimensions of one layer call.
#[derive(Debug, Clone, Copy)]
pub(super) struct Dims {
    pub b: usize,
    pub s: usize,
    pub d: usize,
    pub di: usize,
    pub nh: usize,
    pub dh: usize,
}

pub(super) fn want<'a>(t: &'a Tensor, shape: &[usize], what: &str) -> Result<&'a [f32]> {
    ensure!(
        t.shape.as_slice() == shape,
        "{what}: expected shape {shape:?}, got {:?}",
        t.shape
    );
    t.f32s()
}

/// Token-embedding gather shared by `NativeBackend::embed` and the
/// pretraining step: out[r] = emb[toks[r]].
pub(super) fn embed_gather(
    emb: &[f32],
    vocab: usize,
    d: usize,
    toks: &[i32],
    out: &mut [f32],
) -> Result<()> {
    ensure!(out.len() == toks.len() * d, "embed gather: output size mismatch");
    ensure!(emb.len() == vocab * d, "embed gather: table size mismatch");
    for (r, &tk) in toks.iter().enumerate() {
        ensure!((0..vocab as i32).contains(&tk), "token {tk} out of vocab 0..{vocab}");
        out[r * d..(r + 1) * d].copy_from_slice(&emb[tk as usize * d..(tk as usize + 1) * d]);
    }
    Ok(())
}

/// (in_dim, out_dim) of a projection, with full shape validation.
pub(super) fn proj_dims(p: &Proj, what: &str) -> Result<(usize, usize)> {
    match p {
        Proj::Dense(w) => {
            ensure!(w.shape.len() == 2, "{what}: dense weight must be rank 2");
            Ok((w.shape[0], w.shape[1]))
        }
        Proj::Cured { c, u, r } => {
            ensure!(
                c.shape.len() == 2 && u.shape.len() == 2 && r.shape.len() == 2,
                "{what}: CUR factors must be rank 2"
            );
            let rank = c.shape[1];
            ensure!(
                u.shape == [rank, rank] && r.shape[0] == rank,
                "{what}: inconsistent CUR ranks (C {:?}, U {:?}, R {:?})",
                c.shape,
                u.shape,
                r.shape
            );
            Ok((c.shape[0], r.shape[1]))
        }
    }
}

/// Cached intermediates of a cured projection chain.
pub(super) struct ProjCache {
    /// h·C, (rows × r).
    pub hc: Vec<f32>,
    /// (h·C)·U, (rows × r).
    pub hcu: Vec<f32>,
}

/// Cached intermediates of a blended adapter delta (the switched
/// graphs' backward pass consumes them).
pub(super) struct AdapterCache {
    /// First chain stage, (rows × r): LoRA `x·A`, MoRA `compress(x)`,
    /// CURLoRA `x·C`.
    pub h1: Vec<f32>,
    /// Second chain stage, (rows × r): MoRA `compress(x)·M`, CURLoRA
    /// `(x·C)·U`. Empty for LoRA (its delta is a two-stage chain).
    pub h2: Vec<f32>,
}

/// Validate an adapter's factor shapes against the base projection's
/// (m, n) and return its rank.
pub(super) fn adapter_rank(ad: &ProjAdapter, m: usize, n: usize, what: &str) -> Result<usize> {
    match ad {
        ProjAdapter::Lora { a, b } => {
            ensure!(
                a.shape.len() == 2 && a.shape[0] == m,
                "{what}: lora A must be ({m}, r), got {:?}",
                a.shape
            );
            let r = a.shape[1];
            ensure!(
                b.shape == [r, n],
                "{what}: lora B must be ({r}, {n}), got {:?}",
                b.shape
            );
            Ok(r)
        }
        ProjAdapter::Mora { m: mm } => {
            ensure!(
                mm.shape.len() == 2 && mm.shape[0] == mm.shape[1],
                "{what}: mora M must be square, got {:?}",
                mm.shape
            );
            let r = mm.shape[0];
            ensure!(r <= m && r <= n, "{what}: mora rank {r} exceeds ({m}, {n})");
            Ok(r)
        }
        ProjAdapter::CurLora { c, u, r } => {
            ensure!(
                c.shape.len() == 2 && c.shape[0] == m,
                "{what}: curlora C must be ({m}, r), got {:?}",
                c.shape
            );
            let rank = c.shape[1];
            ensure!(
                u.shape == [rank, rank] && r.shape == [rank, n],
                "{what}: inconsistent curlora factors (C {:?}, U {:?}, R {:?})",
                c.shape,
                u.shape,
                r.shape
            );
            Ok(rank)
        }
    }
}

/// Blend one adapter delta into `out` (+=) and return its cache.
/// The delta is computed separately and added, so a zero-initialized
/// trainable factor (LoRA B, MoRA M, CURLoRA U) leaves the base output
/// numerically untouched — the zero-adapter identity the tests pin.
fn adapter_forward(
    h: &[f32],
    rows: usize,
    ad: &ProjAdapter,
    m: usize,
    n: usize,
    out: &mut [f32],
    what: &str,
) -> Result<AdapterCache> {
    let rank = adapter_rank(ad, m, n, what)?;
    match ad {
        ProjAdapter::Lora { a, b } => {
            let h1 = matmul_nn(h, a.f32s()?, rows, m, rank);
            let delta = matmul_nn(&h1, b.f32s()?, rows, rank, n);
            add_inplace(out, &delta);
            Ok(AdapterCache { h1, h2: Vec::new() })
        }
        ProjAdapter::Mora { m: mm } => {
            let mut h1 = vec![0.0f32; rows * rank];
            mora_compress(h, rows, m, rank, &mut h1);
            let h2 = matmul_nn(&h1, mm.f32s()?, rows, rank, rank);
            // Decompress: out[j] += h2[j / gj].
            let gj = mora_group(n, rank);
            for r in 0..rows {
                let yr = &h2[r * rank..(r + 1) * rank];
                let or = &mut out[r * n..(r + 1) * n];
                for (j, o) in or.iter_mut().enumerate() {
                    *o += yr[j / gj];
                }
            }
            Ok(AdapterCache { h1, h2 })
        }
        ProjAdapter::CurLora { c, u, r } => {
            let h1 = matmul_nn(h, c.f32s()?, rows, m, rank);
            let h2 = matmul_nn(&h1, u.f32s()?, rows, rank, rank);
            let delta = matmul_nn(&h2, r.f32s()?, rows, rank, n);
            add_inplace(out, &delta);
            Ok(AdapterCache { h1, h2 })
        }
    }
}

/// Projection forward: returns the output plus the chain cache when
/// cured, plus the adapter cache when an adapter delta is blended.
pub(super) fn proj_forward(
    h: &[f32],
    rows: usize,
    p: &Proj,
    ad: Option<&ProjAdapter>,
    what: &str,
) -> Result<(Vec<f32>, Option<ProjCache>, Option<AdapterCache>)> {
    let (m, n) = proj_dims(p, what)?;
    ensure!(h.len() == rows * m, "{what}: input is not rows×{m}");
    let (mut out, pc) = match p {
        Proj::Dense(w) => (matmul_nn(h, w.f32s()?, rows, m, n), None),
        Proj::Cured { c, u, r } => {
            let rank = c.shape[1];
            let hc = matmul_nn(h, c.f32s()?, rows, m, rank);
            let hcu = matmul_nn(&hc, u.f32s()?, rows, rank, rank);
            let out = matmul_nn(&hcu, r.f32s()?, rows, rank, n);
            (out, Some(ProjCache { hc, hcu }))
        }
    };
    let ac = match ad {
        Some(ad) => Some(adapter_forward(h, rows, ad, m, n, &mut out, what)?),
        None => None,
    };
    Ok((out, pc, ac))
}

/// Projection forward into a caller-provided buffer, chain scratch reused
/// across calls (the inference path — no per-call allocation).
fn proj_infer(
    h: &[f32],
    rows: usize,
    p: &Proj,
    hc: &mut Vec<f32>,
    hcu: &mut Vec<f32>,
    out: &mut [f32],
    what: &str,
) -> Result<()> {
    let (m, n) = proj_dims(p, what)?;
    ensure!(h.len() == rows * m, "{what}: input is not rows×{m}");
    ensure!(out.len() == rows * n, "{what}: output is not rows×{n}");
    match p {
        Proj::Dense(w) => matmul_nn_into(h, w.f32s()?, rows, m, n, out),
        Proj::Cured { c, u, r } => {
            let rank = c.shape[1];
            let hcb = grow(hc, rows * rank);
            matmul_nn_into(h, c.f32s()?, rows, m, rank, hcb);
            let hcub = grow(hcu, rows * rank);
            matmul_nn_into(&hc[..rows * rank], u.f32s()?, rows, rank, rank, hcub);
            matmul_nn_into(&hcu[..rows * rank], r.f32s()?, rows, rank, n, out);
        }
    }
    Ok(())
}

/// Everything one layer forward produces, kept for the backward pass.
pub(super) struct LayerCache {
    pub dims: Dims,
    /// Post-ln1 attention input, (bs × d).
    pub h1: Vec<f32>,
    pub inv1: Vec<f32>,
    /// q/k post-RoPE, v; all (bs × d).
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Causal softmax probabilities, (b·nh·s·s).
    pub probs: Vec<f32>,
    /// Concatenated head outputs before the o-projection, (bs × d).
    pub att: Vec<f32>,
    /// Post-attention residual stream, (bs × d).
    pub x2: Vec<f32>,
    pub inv2: Vec<f32>,
    /// Post-ln2 FFN input, (bs × d).
    pub h2: Vec<f32>,
    /// Gate pre-activation (bs × di), up branch, silu(g)⊙up.
    pub g: Vec<f32>,
    pub up: Vec<f32>,
    pub act: Vec<f32>,
    /// Layer output, (bs × d).
    pub y: Vec<f32>,
    pub qc: Option<ProjCache>,
    pub kc: Option<ProjCache>,
    pub gc: Option<ProjCache>,
    /// Adapter-delta caches of the switched graphs (None when no
    /// adapter is blended on that projection).
    pub qa: Option<AdapterCache>,
    pub ka: Option<AdapterCache>,
    pub ga: Option<AdapterCache>,
}

pub(super) fn layer_dims(
    n_heads: usize,
    p: &LayerParams,
    b: usize,
    s: usize,
    d: usize,
) -> Result<Dims> {
    ensure!(n_heads > 0 && d % n_heads == 0, "d_model {d} not divisible by {n_heads} heads");
    let dh = d / n_heads;
    ensure!(dh % 2 == 0, "head dim {dh} must be even for RoPE");
    let (qi, qo) = proj_dims(&p.q, "w_q")?;
    let (ki, ko) = proj_dims(&p.k, "w_k")?;
    ensure!(qi == d && qo == d && ki == d && ko == d, "q/k projections must be {d}×{d}");
    let (gi, di) = proj_dims(&p.gate, "w_gate")?;
    ensure!(gi == d, "gate projection input dim {gi} != {d}");
    ensure!(p.up.shape == [d, di], "w_up must be {d}×{di}, got {:?}", p.up.shape);
    ensure!(p.down.shape == [di, d], "w_down must be {di}×{d}, got {:?}", p.down.shape);
    Ok(Dims { b, s, d, di, nh: n_heads, dh })
}

/// One query row's causal attention, the single numeric core every
/// attention path shares: scores over keys 0..=si via [`dot`], a
/// max-subtracted softmax into `prow` (first si+1 entries), then the
/// sj-ascending weighted-V accumulation into `arow` (dh wide). The
/// cached path hands in a persistent probs row, the inference and decode
/// paths a reusable scratch row — bit-identical results by construction.
/// `row0` is the index of this sequence's first row in k/v (bi·s);
/// `hoff` is the head offset h·dh.
#[allow(clippy::too_many_arguments)]
fn attention_row(
    qrow: &[f32],
    k: &[f32],
    v: &[f32],
    row0: usize,
    d: usize,
    hoff: usize,
    si: usize,
    scale: f32,
    prow: &mut [f32],
    arow: &mut [f32],
) {
    let dh = arow.len();
    let mut maxv = f32::NEG_INFINITY;
    for sj in 0..=si {
        let koff = (row0 + sj) * d + hoff;
        let sc = dot(qrow, &k[koff..koff + dh]) * scale;
        prow[sj] = sc;
        if sc > maxv {
            maxv = sc;
        }
    }
    let mut sum = 0.0f32;
    for p in prow.iter_mut().take(si + 1) {
        *p = (*p - maxv).exp();
        sum += *p;
    }
    let isum = 1.0 / sum;
    arow.fill(0.0);
    for sj in 0..=si {
        prow[sj] *= isum;
        let pval = prow[sj];
        let voff = (row0 + sj) * d + hoff;
        for (o, &vv) in arow.iter_mut().zip(&v[voff..voff + dh]) {
            *o += pval * vv;
        }
    }
}

/// One head's causal attention with persisted softmax rows: `probs`
/// (s×s) is kept for the backward pass; `att` (s×dh) is the head output.
fn attention_head(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dims: Dims,
    bi: usize,
    h: usize,
    probs: &mut [f32],
    att: &mut [f32],
) {
    let Dims { s, d, dh, .. } = dims;
    let scale = 1.0 / (dh as f32).sqrt();
    for si in 0..s {
        let qoff = (bi * s + si) * d + h * dh;
        attention_row(
            &q[qoff..qoff + dh],
            k,
            v,
            bi * s,
            d,
            h * dh,
            si,
            scale,
            &mut probs[si * s..(si + 1) * s],
            &mut att[si * dh..(si + 1) * dh],
        );
    }
}

/// Like [`attention_head`] but with a single reusable score row instead
/// of a persisted (s×s) probability block (the inference path).
fn attention_infer_head(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dims: Dims,
    bi: usize,
    h: usize,
    srow: &mut [f32],
    att: &mut [f32],
) {
    let Dims { s, d, dh, .. } = dims;
    let scale = 1.0 / (dh as f32).sqrt();
    for si in 0..s {
        let qoff = (bi * s + si) * d + h * dh;
        attention_row(
            &q[qoff..qoff + dh],
            k,
            v,
            bi * s,
            d,
            h * dh,
            si,
            scale,
            srow,
            &mut att[si * dh..(si + 1) * dh],
        );
    }
}

/// Reassemble per-head outputs (b, nh, s, dh) into the row-major
/// concatenated layout (b·s, nh·dh).
fn heads_to_rows(att_h: &[f32], dims: Dims, out: &mut [f32]) {
    let Dims { b, s, d, nh, dh, .. } = dims;
    for bi in 0..b {
        for h in 0..nh {
            for si in 0..s {
                let src = ((bi * nh + h) * s + si) * dh;
                let dst = (bi * s + si) * d + h * dh;
                out[dst..dst + dh].copy_from_slice(&att_h[src..src + dh]);
            }
        }
    }
}

/// Causal multi-head attention forward, parallel over (batch × heads);
/// returns (softmax probs, concat head outputs).
pub(super) fn attention_fwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dims: Dims,
) -> (Vec<f32>, Vec<f32>) {
    let Dims { b, s, d, nh, dh, .. } = dims;
    let tasks = b * nh;
    let mut probs = vec![0.0f32; tasks * s * s];
    let mut att_h = vec![0.0f32; tasks * s * dh];
    let flops = 2 * tasks * s * s * dh;
    // Each (batch, head) task owns a disjoint probs block and a disjoint
    // head-major output block.
    par_pair_tasks(&mut probs, s * s, &mut att_h, s * dh, tasks, flops, |t, pb, ab| {
        let (bi, h) = (t / nh, t % nh);
        attention_head(q, k, v, dims, bi, h, pb, ab);
    });
    let mut att = vec![0.0f32; b * s * d];
    heads_to_rows(&att_h, dims, &mut att);
    (probs, att)
}

/// Inference attention: same math and order as [`attention_fwd`] but no
/// (b·nh·s·s) probability allocation — each task keeps one score row.
/// Writes head-major outputs into `att_h` and the row-major concat into
/// `att`; `scores` is the sequential-path scratch.
fn attention_infer(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dims: Dims,
    att_h: &mut [f32],
    att: &mut [f32],
    scores: &mut Vec<f32>,
) {
    let Dims { b, s, nh, dh, .. } = dims;
    let tasks = b * nh;
    let flops = 2 * tasks * s * s * dh;
    par_chunk_tasks(att_h, s * dh, tasks, flops, scores, |t, chunk, srow| {
        if srow.len() < s {
            srow.resize(s, 0.0);
        }
        let (bi, h) = (t / nh, t % nh);
        attention_infer_head(q, k, v, dims, bi, h, &mut srow[..s], chunk);
    });
    heads_to_rows(att_h, dims, att);
}

/// One query row's causal attention over a **ring-buffer** K/V lane:
/// the query attends ring coordinates `lo..=hi`, where coordinate `j`
/// lives at ring row `lane_row0 + j % cap` (for the exact policy the
/// coordinates are absolute positions; for a compacted lane they are
/// physical rows with `lo = 0` and no wrap). The
/// score/softmax/accumulate op sequence mirrors [`attention_row`]
/// exactly (scores ascending by coordinate, max-subtracted softmax,
/// ascending weighted-V) — at `lo == 0, cap > hi` the arithmetic is
/// identical, which is what makes ring decode bit-match prefill, the
/// linear-layout oracle, and the compacted lane at keep = 1. The
/// `lo..=hi` span covers at most two contiguous ring runs, so the
/// hot loops carry no modulo.
#[allow(clippy::too_many_arguments)]
fn attention_row_ring(
    qrow: &[f32],
    k: &[f32],
    v: &[f32],
    lane_row0: usize,
    cap: usize,
    d: usize,
    hoff: usize,
    lo: usize,
    hi: usize,
    scale: f32,
    prow: &mut [f32],
    arow: &mut [f32],
) {
    let dh = arow.len();
    let n = hi - lo + 1;
    debug_assert!(n <= cap);
    let start = lo % cap;
    let run1 = n.min(cap - start);
    let mut maxv = f32::NEG_INFINITY;
    let mut idx = 0usize;
    for run in [(start, run1), (0, n - run1)] {
        for rr in run.0..run.0 + run.1 {
            let koff = (lane_row0 + rr) * d + hoff;
            let sc = dot(qrow, &k[koff..koff + dh]) * scale;
            prow[idx] = sc;
            idx += 1;
            if sc > maxv {
                maxv = sc;
            }
        }
    }
    let mut sum = 0.0f32;
    for p in prow.iter_mut().take(n) {
        *p = (*p - maxv).exp();
        sum += *p;
    }
    let isum = 1.0 / sum;
    arow.fill(0.0);
    let mut idx = 0usize;
    for run in [(start, run1), (0, n - run1)] {
        for rr in run.0..run.0 + run.1 {
            prow[idx] *= isum;
            let pval = prow[idx];
            idx += 1;
            let voff = (lane_row0 + rr) * d + hoff;
            for (o, &vv) in arow.iter_mut().zip(&v[voff..voff + dh]) {
                *o += pval * vv;
            }
        }
    }
}

/// One decode row's cache coordinates, computed by the backend from the
/// [`crate::backend::KvCache`] policy before the kernel runs:
///
/// * exact ring — `write = pos % cap`, attention spans ring coordinates
///   `lo..=hi` with `lo = pos+1-min(pos+1, window)`, `hi = pos` (rows
///   read at `coord % cap`);
/// * compacted lane — `write = fill` (append), `lo = 0`, `hi = fill`
///   (the valid prefix plus the just-written row; never wraps since
///   `fill < cap`).
///
/// In both cases `hi % cap == write`, so the entering token always
/// attends its own freshly written K/V row, and iteration ascends by
/// position — the accumulation-order invariant every parity test leans
/// on. `pos` is the absolute RoPE position, decoupled from the physical
/// coordinates.
#[derive(Debug, Clone, Copy)]
pub(super) struct DecodeRow {
    /// Absolute sequence position of the entering token (RoPE rotation).
    pub pos: usize,
    /// Physical lane row receiving the new K/V.
    pub write: usize,
    /// First attended ring coordinate (inclusive).
    pub lo: usize,
    /// Last attended ring coordinate (inclusive; its row is `write`).
    pub hi: usize,
}

/// Fused single-position attention for N independent slots: row `r`
/// queries lane `slots[r]` over the ring coordinates `rows[r].lo..=hi`
/// (see [`DecodeRow`]). `cap` is the lane capacity (`dims.s`).
#[allow(clippy::too_many_arguments)]
fn attention_decode(
    q: &[f32],
    kcache: &[f32],
    vcache: &[f32],
    dims: Dims,
    slots: &[usize],
    rows: &[DecodeRow],
    srow: &mut [f32],
    att: &mut [f32],
) {
    let Dims { s: cap, d, nh, dh, .. } = dims;
    let scale = 1.0 / (dh as f32).sqrt();
    for (r, (&slot, row)) in slots.iter().zip(rows).enumerate() {
        for h in 0..nh {
            let qoff = r * d + h * dh;
            attention_row_ring(
                &q[qoff..qoff + dh],
                kcache,
                vcache,
                slot * cap,
                cap,
                d,
                h * dh,
                row.lo,
                row.hi,
                scale,
                srow,
                &mut att[qoff..qoff + dh],
            );
        }
    }
}

/// Full layer forward with caches. `x` is the flat (bs × d) input.
pub(super) fn layer_forward_cached(
    dims: Dims,
    p: &LayerParams,
    x: &[f32],
) -> Result<LayerCache> {
    let Dims { b, s, d, di, nh, dh } = dims;
    let bs = b * s;
    ensure!(x.len() == bs * d, "layer input length mismatch");
    let ln1 = want(p.ln1, &[d], "ln1")?;
    let ln2 = want(p.ln2, &[d], "ln2")?;
    let wv = want(p.v, &[d, d], "w_v")?;
    let wo = want(p.o, &[d, d], "w_o")?;
    let wup = want(p.up, &[d, di], "w_up")?;
    let wdown = want(p.down, &[di, d], "w_down")?;

    let ad_q = p.adapter.as_ref().and_then(|a| a.q.as_ref());
    let ad_k = p.adapter.as_ref().and_then(|a| a.k.as_ref());
    let ad_g = p.adapter.as_ref().and_then(|a| a.gate.as_ref());
    let (h1, inv1) = rmsnorm_fwd(x, ln1, bs, d);
    let (mut q, qc, qa) = proj_forward(&h1, bs, &p.q, ad_q, "w_q")?;
    let (mut k, kc, ka) = proj_forward(&h1, bs, &p.k, ad_k, "w_k")?;
    let v = matmul_nn(&h1, wv, bs, d, d);
    let rope = rope_tables_cached(s, dh / 2);
    rope_apply(&mut q, b, s, nh, dh, &rope.cos, &rope.sin, 1.0);
    rope_apply(&mut k, b, s, nh, dh, &rope.cos, &rope.sin, 1.0);
    let (probs, att) = attention_fwd(&q, &k, &v, dims);
    let mut x2 = matmul_nn(&att, wo, bs, d, d);
    add_inplace(&mut x2, x);

    let (h2, inv2) = rmsnorm_fwd(&x2, ln2, bs, d);
    let (g, gc, ga) = proj_forward(&h2, bs, &p.gate, ad_g, "w_gate")?;
    let up = matmul_nn(&h2, wup, bs, d, di);
    let mut act = vec![0.0f32; bs * di];
    for i in 0..bs * di {
        act[i] = silu(g[i]) * up[i];
    }
    let mut y = matmul_nn(&act, wdown, bs, di, d);
    add_inplace(&mut y, &x2);

    Ok(LayerCache {
        dims,
        h1,
        inv1,
        q,
        k,
        v,
        probs,
        att,
        x2,
        inv2,
        h2,
        g,
        up,
        act,
        y,
        qc,
        kc,
        gc,
        qa,
        ka,
        ga,
    })
}

/// Reusable buffers of the inference path. One instance lives on the
/// backend and is shared by every layer call — after the first layer at
/// a given shape, a forward performs no intermediate allocations (the
/// output vector is the only fresh buffer).
pub(super) struct InferScratch {
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    att_h: Vec<f32>,
    att: Vec<f32>,
    x2: Vec<f32>,
    g: Vec<f32>,
    up: Vec<f32>,
    hc: Vec<f32>,
    hcu: Vec<f32>,
    scores: Vec<f32>,
    /// Per-row RoPE rotation rows of the decode path (positions are
    /// unbounded, so decode never consults the process-wide table
    /// cache).
    rcos: Vec<f32>,
    rsin: Vec<f32>,
    /// Decode-row coordinates validated by `layer_decode_batch` before
    /// each step. Scratch-owned so steady-state decode performs no
    /// per-step allocation for the batch metadata.
    pub(super) rows: Vec<DecodeRow>,
}

impl InferScratch {
    pub(super) fn new() -> InferScratch {
        InferScratch {
            h: Vec::new(),
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            att_h: Vec::new(),
            att: Vec::new(),
            x2: Vec::new(),
            g: Vec::new(),
            up: Vec::new(),
            hc: Vec::new(),
            hcu: Vec::new(),
            scores: Vec::new(),
            rcos: Vec::new(),
            rsin: Vec::new(),
            rows: Vec::new(),
        }
    }
}

/// Size a scratch buffer and hand out the active prefix.
fn grow(buf: &mut Vec<f32>, n: usize) -> &mut [f32] {
    if buf.len() < n {
        buf.resize(n, 0.0);
    }
    &mut buf[..n]
}

/// Cache-free layer forward. When `kv` is given, the post-RoPE K and the
/// V projection (each bs × d) are copied into it — the prefill step of
/// KV-cached decoding.
pub(super) fn layer_infer_impl(
    dims: Dims,
    p: &LayerParams,
    x: &[f32],
    kv: Option<(&mut [f32], &mut [f32])>,
    sc: &mut InferScratch,
) -> Result<Vec<f32>> {
    let Dims { b, s, d, di, nh, dh } = dims;
    let bs = b * s;
    ensure!(x.len() == bs * d, "layer input length mismatch");
    ensure!(
        p.adapter.as_ref().map(|a| a.is_empty()).unwrap_or(true),
        "the inference path does not blend adapter deltas (use the switched graphs)"
    );
    let ln1 = want(p.ln1, &[d], "ln1")?;
    let ln2 = want(p.ln2, &[d], "ln2")?;
    let wv = want(p.v, &[d, d], "w_v")?;
    let wo = want(p.o, &[d, d], "w_o")?;
    let wup = want(p.up, &[d, di], "w_up")?;
    let wdown = want(p.down, &[di, d], "w_down")?;
    let rope = rope_tables_cached(s, dh / 2);

    let h = {
        let hb = grow(&mut sc.h, bs * d);
        rmsnorm_into(x, ln1, bs, d, hb);
        &*hb
    };
    let q = grow(&mut sc.q, bs * d);
    proj_infer(h, bs, &p.q, &mut sc.hc, &mut sc.hcu, q, "w_q")?;
    let k = grow(&mut sc.k, bs * d);
    proj_infer(h, bs, &p.k, &mut sc.hc, &mut sc.hcu, k, "w_k")?;
    let v = grow(&mut sc.v, bs * d);
    matmul_nn_into(h, wv, bs, d, d, v);
    rope_apply(q, b, s, nh, dh, &rope.cos, &rope.sin, 1.0);
    rope_apply(k, b, s, nh, dh, &rope.cos, &rope.sin, 1.0);
    if let Some((kcache, vcache)) = kv {
        ensure!(kcache.len() == bs * d && vcache.len() == bs * d, "kv cache size mismatch");
        kcache.copy_from_slice(k);
        vcache.copy_from_slice(v);
    }
    let att_h = grow(&mut sc.att_h, bs * d);
    let att = grow(&mut sc.att, bs * d);
    attention_infer(q, k, v, dims, att_h, att, &mut sc.scores);
    let x2 = grow(&mut sc.x2, bs * d);
    matmul_nn_into(att, wo, bs, d, d, x2);
    add_inplace(x2, x);

    let h2 = {
        let hb = grow(&mut sc.h, bs * d);
        rmsnorm_into(x2, ln2, bs, d, hb);
        &*hb
    };
    let g = grow(&mut sc.g, bs * di);
    proj_infer(h2, bs, &p.gate, &mut sc.hc, &mut sc.hcu, g, "w_gate")?;
    let up = grow(&mut sc.up, bs * di);
    matmul_nn_into(h2, wup, bs, d, di, up);
    for i in 0..bs * di {
        g[i] = silu(g[i]) * up[i];
    }
    // curlint: allow(hot-path-purity) -- the layer's output buffer: its ownership moves into the returned Tensor; every intermediate reuses scratch
    let mut y = vec![0.0f32; bs * d];
    matmul_nn_into(g, wdown, bs, di, d, &mut y);
    add_inplace(&mut y, x2);
    Ok(y)
}

/// Fused one-position layer forward for N slots against the cache.
/// `x` is (n × d) — row `r` is the new token's hidden state for slot
/// `slots[r]`, with cache coordinates `rows[r]` (see [`DecodeRow`] for
/// the exact-ring vs compacted-lane layouts). The q/k/v/gate/up/down
/// matmuls each see one n-row activation — the continuous-batching
/// fusion. Writes the new K/V rows, attends each row's `lo..=hi` span,
/// and returns the (n × d) layer output. `dims.b` is n; `dims.s` is the
/// lane capacity `cap`; `kcache`/`vcache` are whole-cache layer buffers
/// (lanes × cap × d).
#[allow(clippy::too_many_arguments)]
pub(super) fn layer_decode_impl(
    dims: Dims,
    p: &LayerParams,
    x: &[f32],
    kcache: &mut [f32],
    vcache: &mut [f32],
    slots: &[usize],
    rows: &[DecodeRow],
    sc: &mut InferScratch,
) -> Result<Vec<f32>> {
    let Dims { b, s: cap, d, di, nh, dh } = dims;
    ensure!(x.len() == b * d, "decode input must be n×d");
    ensure!(
        p.adapter.as_ref().map(|a| a.is_empty()).unwrap_or(true),
        "the decode path does not blend adapter deltas (use the switched graphs)"
    );
    ensure!(slots.len() == b && rows.len() == b, "one slot and cache row per input row");
    let lanes = kcache.len() / (cap * d);
    ensure!(
        kcache.len() == lanes * cap * d && vcache.len() == kcache.len(),
        "kv cache size mismatch"
    );
    for (&slot, row) in slots.iter().zip(rows) {
        ensure!(slot < lanes, "slot {slot} out of cache lanes 0..{lanes}");
        ensure!(
            row.lo <= row.hi && row.hi - row.lo < cap && row.write == row.hi % cap,
            "inconsistent decode coordinates {row:?} for cap {cap}"
        );
    }
    let ln1 = want(p.ln1, &[d], "ln1")?;
    let ln2 = want(p.ln2, &[d], "ln2")?;
    let wv = want(p.v, &[d, d], "w_v")?;
    let wo = want(p.o, &[d, d], "w_o")?;
    let wup = want(p.up, &[d, di], "w_up")?;
    let wdown = want(p.down, &[di, d], "w_down")?;
    // Positions are absolute and unbounded (the ring wraps, RoPE does
    // not) — and client-controlled via n_new, so the process-wide table
    // cache must not grow with them. Compute each row's rotation on the
    // fly into scratch; bit-identical to the cached tables by
    // construction (rope_row_into is their shared per-position core).
    let half = dh / 2;
    let rcos = grow(&mut sc.rcos, b * half);
    let rsin = grow(&mut sc.rsin, b * half);
    for (i, row) in rows.iter().enumerate() {
        rope_row_into(
            row.pos,
            half,
            &mut rcos[i * half..(i + 1) * half],
            &mut rsin[i * half..(i + 1) * half],
        );
    }

    let h = {
        let hb = grow(&mut sc.h, b * d);
        rmsnorm_into(x, ln1, b, d, hb);
        &*hb
    };
    let q = grow(&mut sc.q, b * d);
    proj_infer(h, b, &p.q, &mut sc.hc, &mut sc.hcu, q, "w_q")?;
    let kx = grow(&mut sc.k, b * d);
    proj_infer(h, b, &p.k, &mut sc.hc, &mut sc.hcu, kx, "w_k")?;
    let vx = grow(&mut sc.v, b * d);
    matmul_nn_into(h, wv, b, d, d, vx);
    rope_apply_rows_local(q, b, nh, dh, rcos, rsin);
    rope_apply_rows_local(kx, b, nh, dh, rcos, rsin);
    for (r, (&slot, row)) in slots.iter().zip(rows).enumerate() {
        let dst = (slot * cap + row.write) * d;
        kcache[dst..dst + d].copy_from_slice(&kx[r * d..(r + 1) * d]);
        vcache[dst..dst + d].copy_from_slice(&vx[r * d..(r + 1) * d]);
    }
    let att = grow(&mut sc.att, b * d);
    let srow = grow(&mut sc.scores, cap);
    attention_decode(q, kcache, vcache, dims, slots, rows, srow, att);
    let x2 = grow(&mut sc.x2, b * d);
    matmul_nn_into(att, wo, b, d, d, x2);
    add_inplace(x2, x);

    let h2 = {
        let hb = grow(&mut sc.h, b * d);
        rmsnorm_into(x2, ln2, b, d, hb);
        &*hb
    };
    let g = grow(&mut sc.g, b * di);
    proj_infer(h2, b, &p.gate, &mut sc.hc, &mut sc.hcu, g, "w_gate")?;
    let up = grow(&mut sc.up, b * di);
    matmul_nn_into(h2, wup, b, d, di, up);
    for i in 0..b * di {
        g[i] = silu(g[i]) * up[i];
    }
    // curlint: allow(hot-path-purity) -- the step's output buffer: its ownership moves into the returned Tensor; every intermediate reuses scratch
    let mut y = vec![0.0f32; b * d];
    matmul_nn_into(g, wdown, b, di, d, &mut y);
    add_inplace(&mut y, x2);
    Ok(y)
}

/// Head forward: final RMSNorm then tied-embedding logits. Returns
/// (logits (rows × vocab), xf (rows × d), per-row inverse RMS).
pub(super) fn head_forward(
    x: &[f32],
    ln_f: &[f32],
    emb: &[f32],
    rows: usize,
    d: usize,
    vocab: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (xf, inv) = rmsnorm_fwd(x, ln_f, rows, d);
    let logits = matmul_nt(&xf, emb, rows, d, vocab);
    (logits, xf, inv)
}

/// Per-row negative log-likelihood from logits.
pub(super) fn nll_rows(logits: &[f32], targets: &[i32], rows: usize, vocab: usize) -> Result<Vec<f32>> {
    ensure!(targets.len() == rows, "targets length mismatch");
    let mut out = vec![0.0f32; rows];
    for r in 0..rows {
        let t = targets[r];
        ensure!(
            (0..vocab as i32).contains(&t),
            "target token {t} out of vocab range 0..{vocab}"
        );
        let row = &logits[r * vocab..(r + 1) * vocab];
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let logz =
            maxv as f64 + row.iter().map(|&x| ((x - maxv) as f64).exp()).sum::<f64>().ln();
        out[r] = (logz - row[t as usize] as f64) as f32;
    }
    Ok(out)
}

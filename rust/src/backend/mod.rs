//! Pluggable execution backends.
//!
//! The coordinator composes per-layer model operations (embed, dense or
//! CURed transformer layers, calibration taps, the LM head, train/heal
//! optimizer steps). A [`Backend`] supplies those operations:
//!
//! * [`native`] — pure-Rust CPU reference implementation. Executes the
//!   Llama-mini math directly against host tensors with blocked,
//!   multithreaded matmuls. Always available; needs no artifacts.
//! * `pjrt` (behind the `pjrt` feature) — the AOT artifact executor on
//!   top of the `xla` PJRT crate: loads HLO-text artifacts emitted by the
//!   Python build step and dispatches each operation to its compiled
//!   executable. The accelerator path when `make artifacts` has run.
//!
//! Everything above the backend (pipeline, compression, healing drivers,
//! evaluation, serving) is backend-agnostic: it hands the backend plain
//! tensors plus a [`LayerParams`] view of the weights and gets tensors
//! back.

pub mod fault;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::model::ModelConfig;
use crate::runtime::{ArtifactSpec, Bindings};
use crate::tensor::{Tensor, TensorStore};
use crate::util::Json;
use anyhow::{bail, ensure, Result};
use std::borrow::Cow;
use std::collections::HashMap;

/// One projection's weights: a dense matrix or a CUR factor chain. `u` is
/// the *merged* link matrix `U = U₀ + ΔU` (owned when merged host-side —
/// it is r×r, negligible).
pub enum Proj<'a> {
    Dense(&'a Tensor),
    Cured { c: &'a Tensor, u: Cow<'a, Tensor>, r: &'a Tensor },
}

impl Proj<'_> {
    pub fn is_cured(&self) -> bool {
        matches!(self, Proj::Cured { .. })
    }

    /// CUR rank, if cured.
    pub fn rank(&self) -> Option<usize> {
        match self {
            Proj::Dense(_) => None,
            Proj::Cured { u, .. } => u.shape.first().copied(),
        }
    }
}

/// One projection's PEFT-adapter delta, blended onto the base projection
/// by the switched full-model graphs: `y = base(x) + delta(x)`. The base
/// may itself be dense or CUR-factored — the delta is additive either
/// way, and every family's trainable factor starts at zero (LoRA `B`,
/// MoRA `M`, CURLoRA `U`), so a freshly initialized adapter is exactly
/// inert.
pub enum ProjAdapter<'a> {
    /// LoRA (Hu et al.): `delta = (x·A)·B`, `A` (m, r) normal-init,
    /// `B` (r, n) zero-init. Both train.
    Lora { a: &'a Tensor, b: &'a Tensor },
    /// MoRA (Jiang et al.): `delta = decompress(compress(x)·M)` with a
    /// single square trainable `M` (r, r). Compression sums input
    /// features in contiguous groups of `ceil(m/r)`; decompression
    /// broadcasts each of the r outputs over its contiguous group of
    /// `ceil(n/r)` output features (the papers' parameter-free
    /// "sharing" operators).
    Mora { m: &'a Tensor },
    /// CURLoRA (Fawi): `delta = ((x·C)·U)·R` with `C` (m, r) / `R`
    /// (r, n) frozen inverted-importance slices of `W` and `U` (r, r)
    /// trainable, zero-init.
    CurLora { c: &'a Tensor, u: &'a Tensor, r: &'a Tensor },
}

/// Per-layer adapter deltas for the curable projections. `None` entries
/// blend nothing; [`Adapter::Du`](crate::peft::Adapter) never builds a
/// view at all — its trainable ΔU already lives inside the student's
/// merged `U = U₀ + ΔU`.
#[derive(Default)]
pub struct AdapterView<'a> {
    pub q: Option<ProjAdapter<'a>>,
    pub k: Option<ProjAdapter<'a>>,
    pub gate: Option<ProjAdapter<'a>>,
}

impl AdapterView<'_> {
    pub fn is_empty(&self) -> bool {
        self.q.is_none() && self.k.is_none() && self.gate.is_none()
    }
}

/// One transformer layer's parameters, as the backend consumes them.
/// Only q/k/gate are curable (paper §4.1); the rest are always dense.
/// `adapter` carries the switched graphs' PEFT deltas (blended by the
/// train/heal forward only; `None` everywhere else).
pub struct LayerParams<'a> {
    pub ln1: &'a Tensor,
    pub ln2: &'a Tensor,
    pub q: Proj<'a>,
    pub k: Proj<'a>,
    pub v: &'a Tensor,
    pub o: &'a Tensor,
    pub gate: Proj<'a>,
    pub up: &'a Tensor,
    pub down: &'a Tensor,
    pub adapter: Option<AdapterView<'a>>,
}

/// Which full-model switched step family to run (the PEFT comparison
/// experiments, Figs 5–7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepMode {
    /// Healing: `0.9·KD(T=10) + 0.1·CE` against the dense teacher's
    /// logits on the same batch (KD = T²·KL(teacher‖student) over
    /// temperature-T softmaxes, the standard Hinton scaling).
    Heal,
    /// Task fine-tuning: cross-entropy masked to the answer tokens.
    Task,
}

impl StepMode {
    /// Artifact-name stem (`heal_full` / `task_step`).
    pub fn artifact_stem(&self) -> &'static str {
        match self {
            StepMode::Heal => "heal_full",
            StepMode::Task => "task_step",
        }
    }
}

/// Output of one calibration layer forward (WANDA taps, paper §4.2).
pub struct CalibOut {
    /// Layer output, (b, s, d).
    pub y: Tensor,
    /// Σx² per attention-input feature, (d,).
    pub attn_sumsq: Tensor,
    /// Σx² per FFN-input feature, (d,).
    pub ffn_sumsq: Tensor,
    /// Raw attention input (post-ln1), (b, s, d).
    pub attn_in: Tensor,
    /// Raw FFN input (post-ln2), (b, s, d).
    pub ffn_in: Tensor,
}

/// Output of one layer-wise KD healing step.
pub struct HealOut {
    /// Mean squared error against the teacher layer output.
    pub loss: f64,
    /// The student layer's output (propagated to the next layer).
    pub y_student: Tensor,
}

/// A capability the backend does not implement. The typed payload of
/// every unsupported-operation default on [`Backend`], so callers can
/// downcast and branch on "this backend can't do that" instead of
/// matching message strings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Unsupported {
    /// [`Backend::name`] of the refusing backend.
    pub backend: String,
    /// The refusal, e.g. `has no packed-head kernel`, including any
    /// remedial hint.
    pub op: String,
}

impl std::fmt::Display for Unsupported {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "backend '{}' {}", self.backend, self.op)
    }
}

impl std::error::Error for Unsupported {}

/// A malformed CLI/config spec string (kv policy, fault plan). Typed so
/// the binary can tell usage errors (print the grammar, exit early)
/// from engine failures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecError {
    /// What was wrong, phrased for the terminal.
    pub what: String,
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.what)
    }
}

impl std::error::Error for SpecError {}

/// How a [`KvCache`] retires cached positions once a slot lane is full.
///
/// * [`KvPolicy::Exact`] — the sliding-window ring: the newest write
///   overwrites the oldest ring row, attention spans the last `window`
///   positions, nothing else is ever dropped. The default, and the
///   semantics every parity test is pinned to.
/// * [`KvPolicy::Cur`] — CUR-compressed cache: when a slot lane fills,
///   [`Backend::compress_kv_slot`] keeps roughly `keep × window`
///   positions per layer — the `sinks` oldest stream positions
///   (attention sinks, absolute position `< sinks`) and the `recent`
///   newest rows are always retained; the remaining budget is chosen by
///   value-guided DEIM selection over the cached keys
///   ([`crate::cur::select_kv_positions`]) — and decode continues
///   against the compacted lane with **no recompute**. `keep = 1.0`
///   degenerates to dropping only the single oldest position per step,
///   which is arithmetically identical to the exact ring (asserted in
///   tests); `keep < 1.0` trades tokens-for-bytes and may diverge from
///   the exact-cache oracle once the first compaction runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KvPolicy {
    /// Exact sliding-window ring buffer (drop-oldest only).
    Exact,
    /// CUR-compress full lanes down to `keep × window` positions,
    /// always protecting `sinks` + `recent` positions.
    Cur {
        /// Fraction of the window to retain per compaction, in (0, 1].
        keep: f32,
        /// Stream positions `0..sinks` are never evicted (StreamingLLM
        /// attention sinks).
        sinks: usize,
        /// The newest `recent` cached rows are never evicted.
        recent: usize,
    },
}

impl KvPolicy {
    /// Default protected-sink count for `cur:<keep>` without explicit
    /// sink/recent counts.
    pub const DEFAULT_SINKS: usize = 4;
    /// Default protected-recent count.
    pub const DEFAULT_RECENT: usize = 8;

    /// Parse a CLI spec: `exact`, `cur:<keep>` or
    /// `cur:<keep>:<sinks>:<recent>` (e.g. `cur:0.5`, `cur:0.25:4:8`).
    pub fn parse(s: &str) -> Result<KvPolicy> {
        if s == "exact" {
            return Ok(KvPolicy::Exact);
        }
        let Some(rest) = s.strip_prefix("cur:") else {
            bail!(SpecError {
                what: format!("unknown kv policy '{s}' (exact | cur:<keep>[:<sinks>:<recent>])"),
            });
        };
        let parts: Vec<&str> = rest.split(':').collect();
        ensure!(
            parts.len() == 1 || parts.len() == 3,
            "kv policy '{s}' must be cur:<keep> or cur:<keep>:<sinks>:<recent>"
        );
        let keep: f32 = parts[0].parse().map_err(|_| {
            anyhow::anyhow!(SpecError {
                what: format!("bad keep ratio '{}' in kv policy '{s}'", parts[0]),
            })
        })?;
        ensure!(keep > 0.0 && keep <= 1.0, "keep ratio {keep} must be in (0, 1]");
        let (sinks, recent) = if parts.len() == 3 {
            let sinks: usize = parts[1].parse().map_err(|_| {
                anyhow::anyhow!(SpecError {
                    what: format!("bad sink count '{}' in kv policy '{s}'", parts[1]),
                })
            })?;
            let recent: usize = parts[2].parse().map_err(|_| {
                anyhow::anyhow!(SpecError {
                    what: format!("bad recent count '{}' in kv policy '{s}'", parts[2]),
                })
            })?;
            (sinks, recent)
        } else {
            (Self::DEFAULT_SINKS, Self::DEFAULT_RECENT)
        };
        ensure!(recent >= 1, "kv policy needs recent >= 1 (the newest row must survive)");
        Ok(KvPolicy::Cur { keep, sinks, recent })
    }

    /// Check this policy against an attention window: under
    /// [`KvPolicy::Cur`] the protected set must leave room to evict
    /// (`sinks + recent < window`). [`KvPolicy::parse`] cannot know the
    /// window, so every decode entry point validates before building a
    /// cache (and [`KvCache::with_policy`] asserts it as a backstop).
    pub fn validate(&self, window: usize) -> Result<()> {
        if let KvPolicy::Cur { sinks, recent, .. } = self {
            ensure!(
                sinks + recent < window,
                "kv policy '{self}' protects {} positions but the window holds only {window}",
                sinks + recent
            );
        }
        Ok(())
    }
}

impl std::fmt::Display for KvPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvPolicy::Exact => write!(f, "exact"),
            KvPolicy::Cur { keep, sinks, recent } => {
                write!(f, "cur:{keep}:{sinks}:{recent}")
            }
        }
    }
}

/// Per-slot K/V cache for incremental greedy decode: a sliding-window
/// ring buffer under [`KvPolicy::Exact`], a compacted lane under
/// [`KvPolicy::Cur`].
///
/// Layer `l`'s post-RoPE keys and values live at `k[l]`/`v[l]`, each a
/// flat (slots, cap, d) row-major buffer: slot `i` owns the lane
/// `[i·cap, (i+1)·cap)`. Under the exact policy, the token at absolute
/// sequence position `p` sits at ring row `p % cap`. Positions increase
/// monotonically for the lifetime of a slot; once more than `window`
/// tokens have entered, the newest write simply overwrites the oldest
/// ring row — the sliding window rotates with **no recompute and no
/// cache invalidation**. `next_pos[i]` is the absolute position of slot
/// `i`'s next token (equivalently: how many tokens the slot has seen).
///
/// Under the CUR policy the lane is instead an append-only prefix of
/// `fill[i]` valid rows in ascending-position order; `positions[l][i]`
/// maps each physical row to its absolute position (layers retain
/// *different* position sets after a compaction, so the map is
/// per-layer). When `fill[i] == cap` the lane must be compacted by
/// [`Backend::compress_kv_slot`] before the next token
/// ([`KvCache::needs_compaction`]); decode then appends at row
/// `fill[i]`.
///
/// A cache is filled per slot by [`Backend::layer_prefill`] over the
/// prompt window, then advanced one position per emitted token by
/// [`Backend::layer_decode_batch`] (which reads `next_pos`; callers bump
/// it via [`KvCache::advance`] after the last layer of a token).
///
/// `cap >= window`: the fast path uses `cap == window` (a true ring);
/// the generation parity oracle uses `cap == total tokens` so the same
/// decode code runs against a never-wrapping linear layout.
///
/// # Memory
///
/// Resident footprint ([`KvCache::bytes`]):
///
/// ```text
/// n_layers × 2 (K and V) × slots·cap·d × 4 bytes (f32)
/// ```
///
/// — for the `tiny` config (8 layers, 8 slots, cap=64, d=256) that is
/// 8 MiB, and it grows linearly in every serving knob (slots, window,
/// depth, width). [`KvCache::live_bytes`] counts only rows that hold a
/// cached position. Under the exact policy a streaming slot pins the
/// full window bound, `n_layers × 2 × window·d × 4` bytes per slot,
/// forever. Under `cur:<keep>:<sinks>:<recent>` a lane oscillates
/// between the post-compaction floor of
///
/// ```text
/// n_layers × 2 × max(keep·window, sinks + recent)·d × 4 bytes
/// ```
///
/// and the `window`-row high-water mark that triggers the next
/// compaction, so the steady-state mean sits strictly below the exact
/// bound whenever `keep < 1` — the `kv_cur` bench records that mean
/// against the exact bound above.
pub struct KvCache {
    /// Number of slot lanes (independent sequences).
    pub b: usize,
    /// Ring capacity per lane, in positions.
    pub cap: usize,
    /// Attention span: a query at position p attends the last
    /// min(p+1, window) positions. Always <= cap.
    pub window: usize,
    pub d: usize,
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// Per slot: absolute position of the next token (tokens seen).
    pub next_pos: Vec<usize>,
    /// Eviction policy (see [`KvPolicy`]).
    pub policy: KvPolicy,
    /// Per slot: physical rows in use under [`KvPolicy::Cur`] (the lane
    /// prefix `0..fill` is valid, ascending by position). Unused under
    /// the exact policy, where occupancy is `min(next_pos, cap)`.
    pub fill: Vec<usize>,
    /// `positions[layer][slot][row]` = absolute position cached at that
    /// physical row, for rows `0..fill[slot]`. Only maintained under
    /// [`KvPolicy::Cur`] (empty otherwise); per-layer because each layer
    /// retains its own position set after a compaction.
    pub positions: Vec<Vec<Vec<usize>>>,
    /// Total [`Backend::compress_kv_slot`] compactions run on this cache.
    pub compactions: usize,
}

impl KvCache {
    /// The serving shape: ring capacity equals the attention window.
    pub fn new(n_layers: usize, slots: usize, window: usize, d: usize) -> KvCache {
        Self::with_capacity(n_layers, slots, window, window, d)
    }

    /// Explicit capacity (>= window). `cap > window` never evicts live
    /// positions early; the oracle path uses `cap` = total tokens so the
    /// ring never wraps.
    pub fn with_capacity(
        n_layers: usize,
        slots: usize,
        window: usize,
        cap: usize,
        d: usize,
    ) -> KvCache {
        assert!(window >= 1 && cap >= window, "kv cache needs cap >= window >= 1");
        KvCache {
            b: slots,
            cap,
            window,
            d,
            k: vec![vec![0.0; slots * cap * d]; n_layers],
            v: vec![vec![0.0; slots * cap * d]; n_layers],
            next_pos: vec![0; slots],
            policy: KvPolicy::Exact,
            fill: vec![0; slots],
            positions: Vec::new(),
            compactions: 0,
        }
    }

    /// The serving shape under an explicit eviction policy. Under
    /// [`KvPolicy::Cur`] the protected set must leave room to evict:
    /// `sinks + recent < window`.
    pub fn with_policy(
        n_layers: usize,
        slots: usize,
        window: usize,
        d: usize,
        policy: KvPolicy,
    ) -> KvCache {
        let mut kv = Self::new(n_layers, slots, window, d);
        if let KvPolicy::Cur { sinks, recent, .. } = policy {
            assert!(
                sinks + recent < window,
                "kv policy protects {} positions but the window holds only {window}",
                sinks + recent
            );
            kv.positions = vec![vec![Vec::new(); slots]; n_layers];
        }
        kv.policy = policy;
        kv
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }

    /// Recycle a slot lane for a new request (continuous batching).
    pub fn reset_slot(&mut self, slot: usize) {
        self.next_pos[slot] = 0;
        self.fill[slot] = 0;
        for layer in &mut self.positions {
            layer[slot].clear();
        }
    }

    /// Record that `w` prompt positions were prefilled into `slot`.
    pub fn commit_prefill(&mut self, slot: usize, w: usize) {
        self.next_pos[slot] = w;
        self.fill[slot] = w;
        for layer in &mut self.positions {
            layer[slot] = (0..w).collect();
        }
    }

    /// Bump the given slots by one position (call once per emitted
    /// token, after the last layer's decode pass).
    pub fn advance(&mut self, slots: &[usize]) {
        let compacted = matches!(self.policy, KvPolicy::Cur { .. });
        for &s in slots {
            self.next_pos[s] += 1;
            if compacted {
                self.fill[s] += 1;
            }
        }
    }

    /// Roll back a *partially executed* decode step on `slot`: a fused
    /// [`Backend::layer_decode_batch`] pass that failed mid-stack has
    /// already appended this token's position to the per-layer position
    /// maps of every layer it completed (K/V row writes themselves are
    /// idempotent — the row index depends only on the not-yet-advanced
    /// `next_pos`/`fill`). Truncating every layer's map back to `fill`
    /// makes re-executing the step safe. Call only on a slot whose
    /// current step has NOT been advanced; no-op under
    /// [`KvPolicy::Exact`], which keeps no maps.
    pub fn rollback_token(&mut self, slot: usize) {
        let fill = self.fill[slot];
        for layer in &mut self.positions {
            layer[slot].truncate(fill);
        }
    }

    /// Whether `slot`'s lane is full and must be compacted by
    /// [`Backend::compress_kv_slot`] before the next decode step. Always
    /// false under [`KvPolicy::Exact`] (the ring evicts by overwrite).
    pub fn needs_compaction(&self, slot: usize) -> bool {
        matches!(self.policy, KvPolicy::Cur { .. }) && self.fill[slot] >= self.cap
    }

    /// Rows of `slot`'s lane that hold a cached position.
    pub fn live_rows(&self, slot: usize) -> usize {
        match self.policy {
            KvPolicy::Exact => self.next_pos[slot].min(self.cap),
            KvPolicy::Cur { .. } => self.fill[slot],
        }
    }

    /// Bytes of K/V actually holding cached positions, summed over all
    /// slots: layers × 2 × Σ_slot live_rows(slot) × d × 4. Under the CUR
    /// policy this is what compaction shrinks; [`KvCache::bytes`] (the
    /// allocation) does not move.
    pub fn live_bytes(&self) -> usize {
        let rows: usize = (0..self.b).map(|s| self.live_rows(s)).sum();
        self.k.len() * 2 * rows * self.d * 4
    }

    /// Resident size in bytes: layers × 2 (K and V) × slots·cap·d × 4.
    pub fn bytes(&self) -> usize {
        self.k.len() * 2 * self.b * self.cap * self.d * 4
    }

    /// The exact-policy live-bytes bound for ONE streaming slot:
    /// `n_layers × 2 × window·d × 4` bytes — what a full ring pins for
    /// the life of the slot, and the baseline the compressed cache is
    /// measured against (the `kv_cur` bench and the serve CLI both
    /// report against this).
    pub const fn exact_slot_bound(n_layers: usize, window: usize, d: usize) -> usize {
        n_layers * 2 * window * d * 4
    }
}

/// Whether a switched-graph tensor name is a PEFT adapter parameter
/// (`lora_*` / `mora_*` / `cl_*` suffix after the `L{l}.` part).
pub fn is_adapter_param(name: &str) -> bool {
    let suffix = name.split('.').next_back().unwrap_or("");
    suffix.starts_with("lora_") || suffix.starts_with("mora_") || suffix.starts_with("cl_")
}

/// Whether a switched-graph tensor name is a CUR student factor
/// (`c_` / `u_` / `du_` / `r_` suffix).
pub fn is_cur_param(name: &str) -> bool {
    let suffix = name.split('.').next_back().unwrap_or("");
    suffix.starts_with("c_")
        || suffix.starts_with("u_")
        || suffix.starts_with("du_")
        || suffix.starts_with("r_")
}

/// Layer index of an `L{l}.*` tensor name.
pub fn layer_of(name: &str) -> Option<usize> {
    name.strip_prefix('L')?.split('.').next()?.parse().ok()
}

/// Pre-packed LM-head weights for the decode hot loop: the tied
/// embedding (vocab, d) re-laid out into column panels so the
/// logits matmul streams one contiguous buffer and shares each panel
/// line across all batched decode rows ([`Backend::pack_head`] /
/// [`Backend::head_logits_packed`]). Opaque outside the backend that
/// built it; backends without a packed kernel return `None` from
/// `pack_head` and callers fall back to [`Backend::head_logits`].
///
/// The payload is currently the native backend's panel layout — the
/// only packing implementation. A second packing backend (e.g. a
/// lowered pjrt decode graph with its own device-resident pack) should
/// generalize this into a per-backend payload rather than reuse
/// `PackedB`; callers only ever round-trip the struct between
/// `pack_head` and `head_logits_packed` of the same backend, so the
/// seam itself won't change.
pub struct PackedHead {
    pub vocab: usize,
    pub d: usize,
    pub(crate) packed: crate::backend::native::math::PackedB,
}

/// A model-execution backend. All tensors are host [`Tensor`]s; the
/// backend owns marshalling to whatever representation it executes.
pub trait Backend {
    /// Short identifier ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// Model-configuration manifest (`{"configs": {...}, ...}`).
    fn manifest(&self) -> &Json;

    /// Cumulative executed-operation count (perf accounting).
    fn exec_count(&self) -> u64;

    /// Token embedding: (b, s) i32 tokens × (vocab, d) table → (b, s, d).
    fn embed(&self, cfg: &ModelConfig, emb: &Tensor, tokens: &Tensor) -> Result<Tensor>;

    /// One transformer layer forward: (b, s, d) → (b, s, d).
    fn layer_forward(&self, cfg: &ModelConfig, p: &LayerParams, x: &Tensor) -> Result<Tensor>;

    /// Inference-only layer forward: mathematically identical to
    /// [`Backend::layer_forward`] but free of every backward-pass cache
    /// (no softmax-probs or activation buffers survive the call). The
    /// serving/eval/decode hot path. Default: the plain forward.
    fn layer_forward_infer(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
    ) -> Result<Tensor> {
        self.layer_forward(cfg, p, x)
    }

    /// Whether [`Backend::layer_prefill`] /
    /// [`Backend::layer_decode_batch`] are implemented (KV-cached
    /// greedy decode and the continuous-batching generation server).
    fn supports_kv_decode(&self) -> bool {
        false
    }

    /// Whether model calls require the manifest's exact (batch, seq)
    /// shape (AOT artifact backends compile fixed-shape graphs). The
    /// native backend accepts any leading dims and returns false.
    fn fixed_shape(&self) -> bool {
        true
    }

    /// Prompt-window layer forward for one slot: `x` is (1, w, d) with
    /// `w <= kv.window`; the layer's post-RoPE K and V rows for
    /// positions 0..w are captured into slot `slot`'s lane of
    /// `kv.k[layer]`/`kv.v[layer]`. Output equals `layer_forward_infer`
    /// on the same rows. Called once per request per layer (the
    /// continuous-batching admission step); the ring rotation never
    /// re-enters this path.
    fn layer_prefill(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
        kv: &mut KvCache,
        layer: usize,
        slot: usize,
    ) -> Result<Tensor> {
        let _ = (cfg, p, x, kv, layer, slot);
        bail!(Unsupported {
            backend: self.name().into(),
            op: "has no KV-cache decode path (supports_kv_decode = false)".into(),
        })
    }

    /// Fused one-position layer pass across N independent slots: `x` is
    /// (n, 1, d) — row `r` is the new token's hidden state for slot
    /// `slots[r]`, entering at absolute position `kv.next_pos[slots[r]]`.
    /// The matmuls see one n-row activation instead of n separate 1-row
    /// calls. Each row's K/V is written to its ring position and the row
    /// attends the last min(pos+1, window) cached positions of its own
    /// lane. `kv.next_pos` is NOT bumped (the same positions must hold
    /// for every layer of the token) — callers advance via
    /// [`KvCache::advance`] after the last layer.
    fn layer_decode_batch(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
        kv: &mut KvCache,
        layer: usize,
        slots: &[usize],
    ) -> Result<Tensor> {
        let _ = (cfg, p, x, kv, layer, slots);
        bail!(Unsupported {
            backend: self.name().into(),
            op: "has no KV-cache decode path (supports_kv_decode = false)".into(),
        })
    }

    /// Compact slot `slot`'s full K/V lane down to the cache's
    /// [`KvPolicy::Cur`] keep budget, per layer: stream positions
    /// `< sinks` and the newest `recent` rows are always retained; the
    /// remaining budget is filled by value-guided CUR position selection
    /// over that layer's cached keys
    /// ([`crate::cur::select_kv_positions`] — each key row weighted by
    /// its value-vector norm, then DEIM over the weighted key matrix's
    /// leading left singular vectors). Retained rows are moved to the
    /// lane prefix in ascending-position order and
    /// [`KvCache::fill`]/[`KvCache::positions`] are updated; decode
    /// resumes against the compacted lane with no recompute. At
    /// `keep = 1.0` the selection is bypassed and only the single oldest
    /// position is dropped — bit-identical to the exact ring's eviction.
    ///
    /// Returns the number of positions dropped (per layer — every layer
    /// retains the same count, though not the same positions). Callers
    /// invoke this when [`KvCache::needs_compaction`] reports a full
    /// lane ([`crate::pipeline::Pipeline::decode_step`] does it
    /// automatically).
    fn compress_kv_slot(
        &self,
        cfg: &ModelConfig,
        kv: &mut KvCache,
        slot: usize,
    ) -> Result<usize> {
        let _ = (cfg, kv, slot);
        bail!(Unsupported {
            backend: self.name().into(),
            op: "has no KV-cache compression path (supports_kv_decode = false)".into(),
        })
    }

    /// Pre-pack the tied-embedding LM head for repeated decode-step
    /// logits calls ([`Backend::head_logits_packed`]). `None` (the
    /// default) means this backend has no packed kernel and callers
    /// must use [`Backend::head_logits`].
    fn pack_head(&self, emb: &Tensor) -> Result<Option<PackedHead>> {
        let _ = emb;
        Ok(None)
    }

    /// [`Backend::head_logits`] against a pre-packed head. Only valid
    /// with a `PackedHead` from this backend's [`Backend::pack_head`].
    fn head_logits_packed(
        &self,
        cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        packed: &PackedHead,
    ) -> Result<Tensor> {
        let _ = (cfg, x, ln_f, packed);
        bail!(Unsupported {
            backend: self.name().into(),
            op: "has no packed-head kernel".into(),
        })
    }

    /// Layer forward with calibration taps (dense layers only in practice).
    fn layer_forward_calib(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
    ) -> Result<CalibOut>;

    /// Final-norm + tied-embedding head: (b, s, d) → (b, s, vocab) logits.
    fn head_logits(
        &self,
        cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        emb: &Tensor,
    ) -> Result<Tensor>;

    /// Per-token negative log-likelihood: (b, s, d) × targets → (b, s).
    fn head_nll(
        &self,
        cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        emb: &Tensor,
        targets: &Tensor,
    ) -> Result<Tensor>;

    /// One Adam step of dense-model pretraining (cross-entropy loss).
    /// Updates parameters in `store` and moments (`m.*`/`v.*`) in `opt`
    /// in place; returns the batch loss. `t` is the 1-based step for
    /// Adam bias correction.
    #[allow(clippy::too_many_arguments)]
    fn train_step(
        &self,
        cfg: &ModelConfig,
        store: &mut TensorStore,
        opt: &mut TensorStore,
        tokens: &Tensor,
        targets: &Tensor,
        lr: f32,
        t: f32,
    ) -> Result<f64>;

    /// One layer-wise KD healing step on layer `layer` (paper §4.5):
    /// Adam on the ΔU factors of the layer's cured projections against
    /// the MSE to `y_teacher`. Updates `L{layer}.du_*` in `student` and
    /// `heal.L{layer}.{m,v}.du_*` moments in `opt` in place.
    #[allow(clippy::too_many_arguments)]
    fn heal_step(
        &self,
        cfg: &ModelConfig,
        student: &mut TensorStore,
        opt: &mut TensorStore,
        layer: usize,
        x: &Tensor,
        y_teacher: &Tensor,
        lr: f32,
        t: f32,
    ) -> Result<HealOut>;

    /// One full-model switched optimizer step (the PEFT comparisons,
    /// Figs 5–7): forward the cured `student` with `adapter`'s deltas
    /// blended onto the q/k/gate projections, compute the [`StepMode`]
    /// loss ([`StepMode::Heal`] needs the dense `teacher` for KD), and
    /// Adam-update **only** the active adapter's parameters — ΔU for
    /// `Du` (written to `student`), A/B for LoRA, M for MoRA, U for
    /// CURLoRA (written to `adapters`; C/R stay frozen). Moments live in
    /// `opt` under `{tag}.{m,v}.{name}`. Returns the batch loss.
    ///
    /// Missing tensors of the *active* adapter family, and missing
    /// student factors of a *cured* layer, are hard errors — a typo'd
    /// tensor name must never silently train or evaluate the base model.
    #[allow(clippy::too_many_arguments)]
    fn switched_step(
        &self,
        cfg: &ModelConfig,
        teacher: &TensorStore,
        student: &mut TensorStore,
        adapters: &mut TensorStore,
        opt: &mut TensorStore,
        adapter: crate::peft::Adapter,
        mode: StepMode,
        tokens: &Tensor,
        targets: &Tensor,
        loss_mask: Option<&Tensor>,
        lr: f32,
        t: f32,
    ) -> Result<f64> {
        let _ = (cfg, teacher, student, adapters, opt, adapter, mode, tokens, targets,
                 loss_mask, lr, t);
        bail!(Unsupported {
            backend: self.name().into(),
            op: "has no switched full-model step implementation".into(),
        })
    }

    /// Logits of the adapter-blended student model, (b, s, vocab) — the
    /// eval counterpart of [`Backend::switched_step`], with the same
    /// strict missing-tensor rules.
    fn switched_logits(
        &self,
        cfg: &ModelConfig,
        teacher: &TensorStore,
        student: &TensorStore,
        adapters: &TensorStore,
        adapter: crate::peft::Adapter,
        tokens: &Tensor,
    ) -> Result<Tensor> {
        let _ = (cfg, teacher, student, adapters, adapter, tokens);
        bail!(Unsupported {
            backend: self.name().into(),
            op: "has no switched full-model logits implementation".into(),
        })
    }

    /// Whether this backend can execute arbitrary named AOT artifacts
    /// (the switched full-model train/eval graphs used by the PEFT
    /// comparison experiments).
    fn supports_artifacts(&self) -> bool {
        false
    }

    fn artifact_names(&self) -> Vec<String> {
        Vec::new()
    }

    fn artifact_spec(&self, name: &str) -> Result<ArtifactSpec> {
        bail!(Unsupported {
            backend: self.name().into(),
            op: format!(
                "cannot introspect AOT artifact '{name}' \
                 (build with --features pjrt and run `make artifacts`)"
            ),
        })
    }

    fn execute_artifact(
        &self,
        name: &str,
        bindings: &Bindings,
    ) -> Result<HashMap<String, Tensor>> {
        let _ = bindings;
        bail!(Unsupported {
            backend: self.name().into(),
            op: format!(
                "cannot execute AOT artifact '{name}' \
                 (build with --features pjrt and run `make artifacts`)"
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_classifiers() {
        assert!(is_adapter_param("L3.lora_a_q"));
        assert!(is_adapter_param("L3.mora_m_gate"));
        assert!(is_adapter_param("L3.cl_u_k"));
        assert!(!is_adapter_param("L3.w_q"));
        assert!(is_cur_param("L3.du_q"));
        assert!(is_cur_param("L3.c_gate"));
        assert!(!is_cur_param("L3.w_gate"));
        assert!(!is_cur_param("emb"));
        assert_eq!(layer_of("L3.du_q"), Some(3));
        assert_eq!(layer_of("L12.w_gate"), Some(12));
        assert_eq!(layer_of("emb"), None);
    }

    #[test]
    fn step_mode_stems() {
        assert_eq!(StepMode::Heal.artifact_stem(), "heal_full");
        assert_eq!(StepMode::Task.artifact_stem(), "task_step");
    }
}

//! Pluggable execution backends.
//!
//! The coordinator composes per-layer model operations (embed, dense or
//! CURed transformer layers, calibration taps, the LM head, train/heal
//! optimizer steps). A [`Backend`] supplies those operations:
//!
//! * [`native`] — pure-Rust CPU reference implementation. Executes the
//!   Llama-mini math directly against host tensors with blocked,
//!   multithreaded matmuls. Always available; needs no artifacts.
//! * `pjrt` (behind the `pjrt` feature) — the AOT artifact executor on
//!   top of the `xla` PJRT crate: loads HLO-text artifacts emitted by the
//!   Python build step and dispatches each operation to its compiled
//!   executable. The accelerator path when `make artifacts` has run.
//!
//! Everything above the backend (pipeline, compression, healing drivers,
//! evaluation, serving) is backend-agnostic: it hands the backend plain
//! tensors plus a [`LayerParams`] view of the weights and gets tensors
//! back.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::model::ModelConfig;
use crate::runtime::{ArtifactSpec, Bindings};
use crate::tensor::{Tensor, TensorStore};
use crate::util::Json;
use anyhow::{bail, Result};
use std::borrow::Cow;
use std::collections::HashMap;

/// One projection's weights: a dense matrix or a CUR factor chain. `u` is
/// the *merged* link matrix `U = U₀ + ΔU` (owned when merged host-side —
/// it is r×r, negligible).
pub enum Proj<'a> {
    Dense(&'a Tensor),
    Cured { c: &'a Tensor, u: Cow<'a, Tensor>, r: &'a Tensor },
}

impl Proj<'_> {
    pub fn is_cured(&self) -> bool {
        matches!(self, Proj::Cured { .. })
    }

    /// CUR rank, if cured.
    pub fn rank(&self) -> Option<usize> {
        match self {
            Proj::Dense(_) => None,
            Proj::Cured { u, .. } => u.shape.first().copied(),
        }
    }
}

/// One transformer layer's parameters, as the backend consumes them.
/// Only q/k/gate are curable (paper §4.1); the rest are always dense.
pub struct LayerParams<'a> {
    pub ln1: &'a Tensor,
    pub ln2: &'a Tensor,
    pub q: Proj<'a>,
    pub k: Proj<'a>,
    pub v: &'a Tensor,
    pub o: &'a Tensor,
    pub gate: Proj<'a>,
    pub up: &'a Tensor,
    pub down: &'a Tensor,
}

/// Output of one calibration layer forward (WANDA taps, paper §4.2).
pub struct CalibOut {
    /// Layer output, (b, s, d).
    pub y: Tensor,
    /// Σx² per attention-input feature, (d,).
    pub attn_sumsq: Tensor,
    /// Σx² per FFN-input feature, (d,).
    pub ffn_sumsq: Tensor,
    /// Raw attention input (post-ln1), (b, s, d).
    pub attn_in: Tensor,
    /// Raw FFN input (post-ln2), (b, s, d).
    pub ffn_in: Tensor,
}

/// Output of one layer-wise KD healing step.
pub struct HealOut {
    /// Mean squared error against the teacher layer output.
    pub loss: f64,
    /// The student layer's output (propagated to the next layer).
    pub y_student: Tensor,
}

/// Per-layer K/V buffers for incremental greedy decode: layer `l`'s
/// post-RoPE keys and values live at `k[l]`/`v[l]`, each a flat
/// (b, s, d) row-major buffer. Filled by [`Backend::layer_prefill`] over
/// a full window, then advanced one position per emitted token by
/// [`Backend::layer_decode`].
///
/// Resident footprint: n_layers × 2 × b·s·d × 4 bytes f32 (see
/// [`KvCache::bytes`]) — for the `tiny` config (8 layers, b=8, s=64,
/// d=256) that is 8 MiB.
pub struct KvCache {
    pub b: usize,
    pub s: usize,
    pub d: usize,
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

impl KvCache {
    pub fn new(n_layers: usize, b: usize, s: usize, d: usize) -> KvCache {
        KvCache {
            b,
            s,
            d,
            k: vec![vec![0.0; b * s * d]; n_layers],
            v: vec![vec![0.0; b * s * d]; n_layers],
        }
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }

    /// Resident size in bytes: layers × 2 (K and V) × b·s·d × 4.
    pub fn bytes(&self) -> usize {
        self.k.len() * 2 * self.b * self.s * self.d * 4
    }
}

/// A model-execution backend. All tensors are host [`Tensor`]s; the
/// backend owns marshalling to whatever representation it executes.
pub trait Backend {
    /// Short identifier ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// Model-configuration manifest (`{"configs": {...}, ...}`).
    fn manifest(&self) -> &Json;

    /// Cumulative executed-operation count (perf accounting).
    fn exec_count(&self) -> u64;

    /// Token embedding: (b, s) i32 tokens × (vocab, d) table → (b, s, d).
    fn embed(&self, cfg: &ModelConfig, emb: &Tensor, tokens: &Tensor) -> Result<Tensor>;

    /// One transformer layer forward: (b, s, d) → (b, s, d).
    fn layer_forward(&self, cfg: &ModelConfig, p: &LayerParams, x: &Tensor) -> Result<Tensor>;

    /// Inference-only layer forward: mathematically identical to
    /// [`Backend::layer_forward`] but free of every backward-pass cache
    /// (no softmax-probs or activation buffers survive the call). The
    /// serving/eval/decode hot path. Default: the plain forward.
    fn layer_forward_infer(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
    ) -> Result<Tensor> {
        self.layer_forward(cfg, p, x)
    }

    /// Whether [`Backend::layer_prefill`] / [`Backend::layer_decode`]
    /// are implemented (KV-cached greedy decode).
    fn supports_kv_decode(&self) -> bool {
        false
    }

    /// Whether model calls require the manifest's exact (batch, seq)
    /// shape (AOT artifact backends compile fixed-shape graphs). The
    /// native backend accepts any leading dims and returns false.
    fn fixed_shape(&self) -> bool {
        true
    }

    /// Full-window layer forward that additionally captures the layer's
    /// post-RoPE K and V into `kv.k[layer]`/`kv.v[layer]` — the prefill
    /// step of KV-cached decoding. Output equals `layer_forward_infer`.
    fn layer_prefill(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
        kv: &mut KvCache,
        layer: usize,
    ) -> Result<Tensor> {
        let _ = (cfg, p, x, kv, layer);
        bail!(
            "backend '{}' has no KV-cache decode path (supports_kv_decode = false)",
            self.name()
        )
    }

    /// One-position layer pass for greedy decode: `x` is (b, 1, d) — the
    /// new token's hidden state per batch row, row `i` at sequence
    /// position `pos[i]` — attending the cached keys/values 0..=pos[i]
    /// of `kv` at `layer`, whose cache this call extends in place.
    fn layer_decode(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
        kv: &mut KvCache,
        layer: usize,
        pos: &[usize],
    ) -> Result<Tensor> {
        let _ = (cfg, p, x, kv, layer, pos);
        bail!(
            "backend '{}' has no KV-cache decode path (supports_kv_decode = false)",
            self.name()
        )
    }

    /// Layer forward with calibration taps (dense layers only in practice).
    fn layer_forward_calib(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
    ) -> Result<CalibOut>;

    /// Final-norm + tied-embedding head: (b, s, d) → (b, s, vocab) logits.
    fn head_logits(
        &self,
        cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        emb: &Tensor,
    ) -> Result<Tensor>;

    /// Per-token negative log-likelihood: (b, s, d) × targets → (b, s).
    fn head_nll(
        &self,
        cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        emb: &Tensor,
        targets: &Tensor,
    ) -> Result<Tensor>;

    /// One Adam step of dense-model pretraining (cross-entropy loss).
    /// Updates parameters in `store` and moments (`m.*`/`v.*`) in `opt`
    /// in place; returns the batch loss. `t` is the 1-based step for
    /// Adam bias correction.
    #[allow(clippy::too_many_arguments)]
    fn train_step(
        &self,
        cfg: &ModelConfig,
        store: &mut TensorStore,
        opt: &mut TensorStore,
        tokens: &Tensor,
        targets: &Tensor,
        lr: f32,
        t: f32,
    ) -> Result<f64>;

    /// One layer-wise KD healing step on layer `layer` (paper §4.5):
    /// Adam on the ΔU factors of the layer's cured projections against
    /// the MSE to `y_teacher`. Updates `L{layer}.du_*` in `student` and
    /// `heal.L{layer}.{m,v}.du_*` moments in `opt` in place.
    #[allow(clippy::too_many_arguments)]
    fn heal_step(
        &self,
        cfg: &ModelConfig,
        student: &mut TensorStore,
        opt: &mut TensorStore,
        layer: usize,
        x: &Tensor,
        y_teacher: &Tensor,
        lr: f32,
        t: f32,
    ) -> Result<HealOut>;

    /// Whether this backend can execute arbitrary named AOT artifacts
    /// (the switched full-model train/eval graphs used by the PEFT
    /// comparison experiments).
    fn supports_artifacts(&self) -> bool {
        false
    }

    fn artifact_names(&self) -> Vec<String> {
        Vec::new()
    }

    fn artifact_spec(&self, name: &str) -> Result<ArtifactSpec> {
        bail!(
            "backend '{}' cannot introspect AOT artifact '{name}' \
             (build with --features pjrt and run `make artifacts`)",
            self.name()
        )
    }

    fn execute_artifact(
        &self,
        name: &str,
        bindings: &Bindings,
    ) -> Result<HashMap<String, Tensor>> {
        let _ = bindings;
        bail!(
            "backend '{}' cannot execute AOT artifact '{name}' \
             (build with --features pjrt and run `make artifacts`)",
            self.name()
        )
    }
}

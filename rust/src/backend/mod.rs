//! Pluggable execution backends.
//!
//! The coordinator composes per-layer model operations (embed, dense or
//! CURed transformer layers, calibration taps, the LM head, train/heal
//! optimizer steps). A [`Backend`] supplies those operations:
//!
//! * [`native`] — pure-Rust CPU reference implementation. Executes the
//!   Llama-mini math directly against host tensors with blocked,
//!   multithreaded matmuls. Always available; needs no artifacts.
//! * `pjrt` (behind the `pjrt` feature) — the AOT artifact executor on
//!   top of the `xla` PJRT crate: loads HLO-text artifacts emitted by the
//!   Python build step and dispatches each operation to its compiled
//!   executable. The accelerator path when `make artifacts` has run.
//!
//! Everything above the backend (pipeline, compression, healing drivers,
//! evaluation, serving) is backend-agnostic: it hands the backend plain
//! tensors plus a [`LayerParams`] view of the weights and gets tensors
//! back.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::model::ModelConfig;
use crate::runtime::{ArtifactSpec, Bindings};
use crate::tensor::{Tensor, TensorStore};
use crate::util::Json;
use anyhow::{bail, Result};
use std::borrow::Cow;
use std::collections::HashMap;

/// One projection's weights: a dense matrix or a CUR factor chain. `u` is
/// the *merged* link matrix `U = U₀ + ΔU` (owned when merged host-side —
/// it is r×r, negligible).
pub enum Proj<'a> {
    Dense(&'a Tensor),
    Cured { c: &'a Tensor, u: Cow<'a, Tensor>, r: &'a Tensor },
}

impl Proj<'_> {
    pub fn is_cured(&self) -> bool {
        matches!(self, Proj::Cured { .. })
    }

    /// CUR rank, if cured.
    pub fn rank(&self) -> Option<usize> {
        match self {
            Proj::Dense(_) => None,
            Proj::Cured { u, .. } => u.shape.first().copied(),
        }
    }
}

/// One transformer layer's parameters, as the backend consumes them.
/// Only q/k/gate are curable (paper §4.1); the rest are always dense.
pub struct LayerParams<'a> {
    pub ln1: &'a Tensor,
    pub ln2: &'a Tensor,
    pub q: Proj<'a>,
    pub k: Proj<'a>,
    pub v: &'a Tensor,
    pub o: &'a Tensor,
    pub gate: Proj<'a>,
    pub up: &'a Tensor,
    pub down: &'a Tensor,
}

/// Output of one calibration layer forward (WANDA taps, paper §4.2).
pub struct CalibOut {
    /// Layer output, (b, s, d).
    pub y: Tensor,
    /// Σx² per attention-input feature, (d,).
    pub attn_sumsq: Tensor,
    /// Σx² per FFN-input feature, (d,).
    pub ffn_sumsq: Tensor,
    /// Raw attention input (post-ln1), (b, s, d).
    pub attn_in: Tensor,
    /// Raw FFN input (post-ln2), (b, s, d).
    pub ffn_in: Tensor,
}

/// Output of one layer-wise KD healing step.
pub struct HealOut {
    /// Mean squared error against the teacher layer output.
    pub loss: f64,
    /// The student layer's output (propagated to the next layer).
    pub y_student: Tensor,
}

/// Per-slot ring-buffer K/V for incremental greedy decode.
///
/// Layer `l`'s post-RoPE keys and values live at `k[l]`/`v[l]`, each a
/// flat (slots, cap, d) row-major buffer: slot `i` owns the lane
/// `[i·cap, (i+1)·cap)`, and the token at absolute sequence position `p`
/// sits at ring row `p % cap`. Positions increase monotonically for the
/// lifetime of a slot; once more than `window` tokens have entered, the
/// newest write simply overwrites the oldest ring row — the sliding
/// window rotates with **no recompute and no cache invalidation**.
/// `next_pos[i]` is the absolute position of slot `i`'s next token
/// (equivalently: how many tokens the slot has seen).
///
/// A cache is filled per slot by [`Backend::layer_prefill`] over the
/// prompt window, then advanced one position per emitted token by
/// [`Backend::layer_decode_batch`] (which reads `next_pos`; callers bump
/// it via [`KvCache::advance`] after the last layer of a token).
///
/// `cap >= window`: the fast path uses `cap == window` (a true ring);
/// the generation parity oracle uses `cap == total tokens` so the same
/// decode code runs against a never-wrapping linear layout.
///
/// Resident footprint: n_layers × 2 × slots·cap·d × 4 bytes f32 (see
/// [`KvCache::bytes`]) — for the `tiny` config (8 layers, 8 slots,
/// cap=64, d=256) that is 8 MiB.
pub struct KvCache {
    /// Number of slot lanes (independent sequences).
    pub b: usize,
    /// Ring capacity per lane, in positions.
    pub cap: usize,
    /// Attention span: a query at position p attends the last
    /// min(p+1, window) positions. Always <= cap.
    pub window: usize,
    pub d: usize,
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// Per slot: absolute position of the next token (tokens seen).
    pub next_pos: Vec<usize>,
}

impl KvCache {
    /// The serving shape: ring capacity equals the attention window.
    pub fn new(n_layers: usize, slots: usize, window: usize, d: usize) -> KvCache {
        Self::with_capacity(n_layers, slots, window, window, d)
    }

    /// Explicit capacity (>= window). `cap > window` never evicts live
    /// positions early; the oracle path uses `cap` = total tokens so the
    /// ring never wraps.
    pub fn with_capacity(
        n_layers: usize,
        slots: usize,
        window: usize,
        cap: usize,
        d: usize,
    ) -> KvCache {
        assert!(window >= 1 && cap >= window, "kv cache needs cap >= window >= 1");
        KvCache {
            b: slots,
            cap,
            window,
            d,
            k: vec![vec![0.0; slots * cap * d]; n_layers],
            v: vec![vec![0.0; slots * cap * d]; n_layers],
            next_pos: vec![0; slots],
        }
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }

    /// Recycle a slot lane for a new request (continuous batching).
    pub fn reset_slot(&mut self, slot: usize) {
        self.next_pos[slot] = 0;
    }

    /// Record that `w` prompt positions were prefilled into `slot`.
    pub fn commit_prefill(&mut self, slot: usize, w: usize) {
        self.next_pos[slot] = w;
    }

    /// Bump the given slots by one position (call once per emitted
    /// token, after the last layer's decode pass).
    pub fn advance(&mut self, slots: &[usize]) {
        for &s in slots {
            self.next_pos[s] += 1;
        }
    }

    /// Resident size in bytes: layers × 2 (K and V) × slots·cap·d × 4.
    pub fn bytes(&self) -> usize {
        self.k.len() * 2 * self.b * self.cap * self.d * 4
    }
}

/// Pre-packed LM-head weights for the decode hot loop: the tied
/// embedding (vocab, d) re-laid out into column panels so the
/// logits matmul streams one contiguous buffer and shares each panel
/// line across all batched decode rows ([`Backend::pack_head`] /
/// [`Backend::head_logits_packed`]). Opaque outside the backend that
/// built it; backends without a packed kernel return `None` from
/// `pack_head` and callers fall back to [`Backend::head_logits`].
///
/// The payload is currently the native backend's panel layout — the
/// only packing implementation. A second packing backend (e.g. a
/// lowered pjrt decode graph with its own device-resident pack) should
/// generalize this into a per-backend payload rather than reuse
/// `PackedB`; callers only ever round-trip the struct between
/// `pack_head` and `head_logits_packed` of the same backend, so the
/// seam itself won't change.
pub struct PackedHead {
    pub vocab: usize,
    pub d: usize,
    pub(crate) packed: crate::backend::native::math::PackedB,
}

/// A model-execution backend. All tensors are host [`Tensor`]s; the
/// backend owns marshalling to whatever representation it executes.
pub trait Backend {
    /// Short identifier ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// Model-configuration manifest (`{"configs": {...}, ...}`).
    fn manifest(&self) -> &Json;

    /// Cumulative executed-operation count (perf accounting).
    fn exec_count(&self) -> u64;

    /// Token embedding: (b, s) i32 tokens × (vocab, d) table → (b, s, d).
    fn embed(&self, cfg: &ModelConfig, emb: &Tensor, tokens: &Tensor) -> Result<Tensor>;

    /// One transformer layer forward: (b, s, d) → (b, s, d).
    fn layer_forward(&self, cfg: &ModelConfig, p: &LayerParams, x: &Tensor) -> Result<Tensor>;

    /// Inference-only layer forward: mathematically identical to
    /// [`Backend::layer_forward`] but free of every backward-pass cache
    /// (no softmax-probs or activation buffers survive the call). The
    /// serving/eval/decode hot path. Default: the plain forward.
    fn layer_forward_infer(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
    ) -> Result<Tensor> {
        self.layer_forward(cfg, p, x)
    }

    /// Whether [`Backend::layer_prefill`] /
    /// [`Backend::layer_decode_batch`] are implemented (KV-cached
    /// greedy decode and the continuous-batching generation server).
    fn supports_kv_decode(&self) -> bool {
        false
    }

    /// Whether model calls require the manifest's exact (batch, seq)
    /// shape (AOT artifact backends compile fixed-shape graphs). The
    /// native backend accepts any leading dims and returns false.
    fn fixed_shape(&self) -> bool {
        true
    }

    /// Prompt-window layer forward for one slot: `x` is (1, w, d) with
    /// `w <= kv.window`; the layer's post-RoPE K and V rows for
    /// positions 0..w are captured into slot `slot`'s lane of
    /// `kv.k[layer]`/`kv.v[layer]`. Output equals `layer_forward_infer`
    /// on the same rows. Called once per request per layer (the
    /// continuous-batching admission step); the ring rotation never
    /// re-enters this path.
    fn layer_prefill(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
        kv: &mut KvCache,
        layer: usize,
        slot: usize,
    ) -> Result<Tensor> {
        let _ = (cfg, p, x, kv, layer, slot);
        bail!(
            "backend '{}' has no KV-cache decode path (supports_kv_decode = false)",
            self.name()
        )
    }

    /// Fused one-position layer pass across N independent slots: `x` is
    /// (n, 1, d) — row `r` is the new token's hidden state for slot
    /// `slots[r]`, entering at absolute position `kv.next_pos[slots[r]]`.
    /// The matmuls see one n-row activation instead of n separate 1-row
    /// calls. Each row's K/V is written to its ring position and the row
    /// attends the last min(pos+1, window) cached positions of its own
    /// lane. `kv.next_pos` is NOT bumped (the same positions must hold
    /// for every layer of the token) — callers advance via
    /// [`KvCache::advance`] after the last layer.
    fn layer_decode_batch(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
        kv: &mut KvCache,
        layer: usize,
        slots: &[usize],
    ) -> Result<Tensor> {
        let _ = (cfg, p, x, kv, layer, slots);
        bail!(
            "backend '{}' has no KV-cache decode path (supports_kv_decode = false)",
            self.name()
        )
    }

    /// Pre-pack the tied-embedding LM head for repeated decode-step
    /// logits calls ([`Backend::head_logits_packed`]). `None` (the
    /// default) means this backend has no packed kernel and callers
    /// must use [`Backend::head_logits`].
    fn pack_head(&self, emb: &Tensor) -> Result<Option<PackedHead>> {
        let _ = emb;
        Ok(None)
    }

    /// [`Backend::head_logits`] against a pre-packed head. Only valid
    /// with a `PackedHead` from this backend's [`Backend::pack_head`].
    fn head_logits_packed(
        &self,
        cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        packed: &PackedHead,
    ) -> Result<Tensor> {
        let _ = (cfg, x, ln_f, packed);
        bail!("backend '{}' has no packed-head kernel", self.name())
    }

    /// Layer forward with calibration taps (dense layers only in practice).
    fn layer_forward_calib(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
    ) -> Result<CalibOut>;

    /// Final-norm + tied-embedding head: (b, s, d) → (b, s, vocab) logits.
    fn head_logits(
        &self,
        cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        emb: &Tensor,
    ) -> Result<Tensor>;

    /// Per-token negative log-likelihood: (b, s, d) × targets → (b, s).
    fn head_nll(
        &self,
        cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        emb: &Tensor,
        targets: &Tensor,
    ) -> Result<Tensor>;

    /// One Adam step of dense-model pretraining (cross-entropy loss).
    /// Updates parameters in `store` and moments (`m.*`/`v.*`) in `opt`
    /// in place; returns the batch loss. `t` is the 1-based step for
    /// Adam bias correction.
    #[allow(clippy::too_many_arguments)]
    fn train_step(
        &self,
        cfg: &ModelConfig,
        store: &mut TensorStore,
        opt: &mut TensorStore,
        tokens: &Tensor,
        targets: &Tensor,
        lr: f32,
        t: f32,
    ) -> Result<f64>;

    /// One layer-wise KD healing step on layer `layer` (paper §4.5):
    /// Adam on the ΔU factors of the layer's cured projections against
    /// the MSE to `y_teacher`. Updates `L{layer}.du_*` in `student` and
    /// `heal.L{layer}.{m,v}.du_*` moments in `opt` in place.
    #[allow(clippy::too_many_arguments)]
    fn heal_step(
        &self,
        cfg: &ModelConfig,
        student: &mut TensorStore,
        opt: &mut TensorStore,
        layer: usize,
        x: &Tensor,
        y_teacher: &Tensor,
        lr: f32,
        t: f32,
    ) -> Result<HealOut>;

    /// Whether this backend can execute arbitrary named AOT artifacts
    /// (the switched full-model train/eval graphs used by the PEFT
    /// comparison experiments).
    fn supports_artifacts(&self) -> bool {
        false
    }

    fn artifact_names(&self) -> Vec<String> {
        Vec::new()
    }

    fn artifact_spec(&self, name: &str) -> Result<ArtifactSpec> {
        bail!(
            "backend '{}' cannot introspect AOT artifact '{name}' \
             (build with --features pjrt and run `make artifacts`)",
            self.name()
        )
    }

    fn execute_artifact(
        &self,
        name: &str,
        bindings: &Bindings,
    ) -> Result<HashMap<String, Tensor>> {
        let _ = bindings;
        bail!(
            "backend '{}' cannot execute AOT artifact '{name}' \
             (build with --features pjrt and run `make artifacts`)",
            self.name()
        )
    }
}

//! Deterministic fault injection: a [`Backend`] wrapper that makes the
//! serving stack's failure paths testable.
//!
//! [`FaultyBackend`] wraps any inner backend and, driven by a seeded
//! [`FaultPlan`], injects three failure shapes at the four call sites
//! the generation server exercises per token —
//! [`Backend::layer_prefill`], [`Backend::layer_decode_batch`],
//! [`Backend::compress_kv_slot`] and the head calls
//! ([`Backend::head_logits`] / [`Backend::head_logits_packed`] /
//! [`Backend::head_nll`]):
//!
//! * **typed errors** — the call fails with a downcastable
//!   [`InjectedFault`] instead of running;
//! * **NaN/Inf poisoning** — the call runs, then ONE element of ONE
//!   output row is overwritten with a non-finite value. Because every
//!   kernel is row-independent (see `backend::native::math`), the
//!   corruption is confined to a single slot's stream and must surface
//!   as that one request's typed error, never as cross-slot divergence;
//! * **latency spikes** — the call sleeps `delay<ms>` first, then runs
//!   normally (deadline/timeout fuel);
//! * **crashes** — the calling thread panics with a downcastable
//!   [`InjectedCrash`] payload (deterministic worker death for the
//!   cluster supervisor in [`crate::serve::cluster`]).
//!
//! Injection decisions come from a PCG stream seeded by
//! [`FaultPlan::seed`]: the same plan over the same call sequence hits
//! the same sites (asserted in `tests/chaos.rs`). Everything outside the
//! four sites delegates untouched, so scoring-only paths and
//! train/heal/compress flows see the inner backend verbatim.
//!
//! The plan is normally supplied via the `CURING_FAULTS` environment
//! variable (read by [`crate::util::config::faults_spec`], applied in
//! `Runtime::open_default`) or the serve CLI's `--faults` flag; the
//! grammar lives at [`FaultPlan::parse`].

use crate::backend::{
    Backend, CalibOut, HealOut, KvCache, LayerParams, PackedHead, SpecError, StepMode,
};
use crate::model::ModelConfig;
use crate::runtime::{ArtifactSpec, Bindings};
use crate::tensor::{Tensor, TensorStore};
use crate::util::{Json, Rng};
use anyhow::{bail, ensure, Result};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;

/// A backend call site faults can target.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// [`Backend::layer_prefill`] (admission).
    Prefill,
    /// [`Backend::layer_decode_batch`] (the fused decode hot loop).
    Decode,
    /// [`Backend::compress_kv_slot`] (CUR lane compaction).
    Compress,
    /// The head calls: [`Backend::head_logits`],
    /// [`Backend::head_logits_packed`] and [`Backend::head_nll`].
    Head,
}

impl FaultSite {
    pub const ALL: [FaultSite; 4] =
        [FaultSite::Prefill, FaultSite::Decode, FaultSite::Compress, FaultSite::Head];

    fn parse(s: &str) -> Result<FaultSite> {
        Ok(match s {
            "prefill" => FaultSite::Prefill,
            "decode" => FaultSite::Decode,
            "compress" => FaultSite::Compress,
            "head" => FaultSite::Head,
            other => bail!("unknown fault site '{other}' (prefill|decode|compress|head|all)"),
        })
    }
}

impl std::fmt::Display for FaultSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FaultSite::Prefill => "prefill",
            FaultSite::Decode => "decode",
            FaultSite::Compress => "compress",
            FaultSite::Head => "head",
        })
    }
}

/// What an injection does to the targeted call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Fail the call with a typed [`InjectedFault`] error.
    Error,
    /// Run the call, then overwrite one output element with NaN.
    Nan,
    /// Run the call, then overwrite one output element with +Inf.
    Inf,
    /// Sleep this many milliseconds, then run the call normally.
    Delay(u64),
    /// Panic the calling thread with a downcastable [`InjectedCrash`]
    /// payload — deterministic worker death for the cluster
    /// supervisor's `catch_unwind` boundary. The call never runs.
    Crash,
}

impl FaultKind {
    fn parse(s: &str) -> Result<FaultKind> {
        if let Some(ms) = s.strip_prefix("delay") {
            let ms: u64 = ms
                .parse()
                .map_err(|_| anyhow::anyhow!("bad delay '{s}' (want delay<ms>, e.g. delay5)"))?;
            return Ok(FaultKind::Delay(ms));
        }
        Ok(match s {
            "err" => FaultKind::Error,
            "nan" => FaultKind::Nan,
            "inf" => FaultKind::Inf,
            "crash" => FaultKind::Crash,
            other => bail!("unknown fault kind '{other}' (err|nan|inf|delay<ms>|crash)"),
        })
    }
}

impl std::fmt::Display for FaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultKind::Error => f.write_str("err"),
            FaultKind::Nan => f.write_str("nan"),
            FaultKind::Inf => f.write_str("inf"),
            FaultKind::Delay(ms) => write!(f, "delay{ms}"),
            FaultKind::Crash => f.write_str("crash"),
        }
    }
}

/// One injection rule: at `site`, with per-call probability `p`, do
/// `kind`. A site may carry several rules (e.g. mostly delays plus rare
/// hard errors); each rule draws independently and the first hit wins.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultRule {
    pub site: FaultSite,
    pub p: f64,
    pub kind: FaultKind,
}

/// A seeded fault schedule for one [`FaultyBackend`].
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    /// PCG seed for the injection stream. Same seed + same call
    /// sequence = same injected sites.
    pub seed: u64,
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// Parse a `CURING_FAULTS` / `--faults` spec.
    ///
    /// Grammar — `;`-separated clauses:
    ///
    /// ```text
    /// seed=<u64>                         injection-stream seed (default 0)
    /// <site>=<p>[:<kind>]                one rule; kind defaults to err
    /// all=<p>[:<kind>]                   sugar: one rule per site
    /// site ∈ prefill|decode|compress|head
    /// kind ∈ err|nan|inf|delay<ms>|crash
    /// ```
    ///
    /// Example: `seed=7;decode=0.05;head=0.01:nan;prefill=0.02:delay5`.
    /// Probabilities must lie in [0, 1]; unknown sites/kinds are errors
    /// (a typo'd spec must never silently run fault-free).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let Some((key, val)) = clause.split_once('=') else {
                bail!(SpecError { what: format!("fault clause '{clause}' is not key=value") });
            };
            if key == "seed" {
                plan.seed = val.parse().map_err(|_| {
                    anyhow::anyhow!(SpecError {
                        what: format!("bad fault seed '{val}' (want u64)"),
                    })
                })?;
                continue;
            }
            let (p_str, kind) = match val.split_once(':') {
                Some((p, k)) => (p, FaultKind::parse(k)?),
                None => (val, FaultKind::Error),
            };
            let p: f64 = p_str.parse().map_err(|_| {
                anyhow::anyhow!(SpecError {
                    what: format!("bad fault probability '{p_str}' in '{clause}'"),
                })
            })?;
            ensure!((0.0..=1.0).contains(&p), "fault probability {p} must be in [0, 1]");
            if key == "all" {
                plan.rules.extend(FaultSite::ALL.map(|site| FaultRule { site, p, kind }));
            } else {
                plan.rules.push(FaultRule { site: FaultSite::parse(key)?, p, kind });
            }
        }
        ensure!(!plan.rules.is_empty(), "fault spec '{spec}' defines no rules");
        Ok(plan)
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "seed={}", self.seed)?;
        for r in &self.rules {
            write!(f, ";{}={}:{}", r.site, r.p, r.kind)?;
        }
        Ok(())
    }
}

/// The typed error an injected [`FaultKind::Error`] raises — downcast
/// from the anyhow chain (`err.downcast_ref::<InjectedFault>()`) to
/// distinguish injected faults from organic backend errors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedFault {
    pub site: FaultSite,
    /// 1-based ordinal of this injection on its backend (observability:
    /// "the 3rd injected fault").
    pub seq: u64,
}

impl std::fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected fault #{} at {}", self.seq, self.site)
    }
}

impl std::error::Error for InjectedFault {}

/// The panic payload of an injected [`FaultKind::Crash`]. The cluster
/// supervisor's `catch_unwind` boundary downcasts the payload to tell
/// injected worker deaths from organic panics; a standalone server hit
/// by a `crash` rule simply dies, which is the point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedCrash {
    pub site: FaultSite,
    /// 1-based ordinal of this injection on its backend.
    pub seq: u64,
}

impl std::fmt::Display for InjectedCrash {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected crash #{} at {}", self.seq, self.site)
    }
}

/// Stop the default panic hook from printing a "thread panicked"
/// report for [`InjectedCrash`] payloads — the supervisor catches and
/// accounts for them, so the stderr noise would only drown real
/// panics (which still report through the previously installed hook).
pub fn mute_injected_crash_reports() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<InjectedCrash>().is_none() {
                prev(info);
            }
        }));
    });
}

/// A [`Backend`] that injects the faults of a [`FaultPlan`] around an
/// inner backend. Interior mutability mirrors the inner backends' op
/// counters: the server single-threads all backend calls, and the
/// wrapper (like the handles it wraps) is not `Sync`.
pub struct FaultyBackend {
    inner: Box<dyn Backend>,
    plan: FaultPlan,
    rng: RefCell<Rng>,
    injected: Cell<u64>,
}

impl FaultyBackend {
    pub fn new(inner: Box<dyn Backend>, plan: FaultPlan) -> FaultyBackend {
        let rng = RefCell::new(Rng::new(plan.seed, 0xFA17));
        FaultyBackend { inner, plan, rng, injected: Cell::new(0) }
    }

    /// Total faults injected so far (errors + poisonings + delays).
    pub fn injected(&self) -> u64 {
        self.injected.get()
    }

    /// Draw this site's rules in plan order; the first hit wins. Every
    /// matching rule consumes exactly one draw whether it hits or not,
    /// so the decision stream depends only on (seed, rules, call
    /// sequence) — the determinism the chaos tests pin.
    fn arm(&self, site: FaultSite) -> Option<FaultKind> {
        let mut rng = self.rng.borrow_mut();
        let mut hit = None;
        for rule in self.plan.rules.iter().filter(|r| r.site == site) {
            let draw = rng.f64();
            if hit.is_none() && draw < rule.p {
                hit = Some(rule.kind);
            }
        }
        hit
    }

    fn fault_err(&self, site: FaultSite) -> anyhow::Error {
        let seq = self.injected.get() + 1;
        self.injected.set(seq);
        anyhow::Error::new(InjectedFault { site, seq })
    }

    /// Pre-call gate: raise injected errors, apply delays and crashes,
    /// and hand poison kinds back for post-call application.
    fn pre(&self, site: FaultSite) -> Result<Option<FaultKind>> {
        match self.arm(site) {
            None => Ok(None),
            Some(FaultKind::Error) => Err(self.fault_err(site)),
            Some(FaultKind::Delay(ms)) => {
                self.injected.set(self.injected.get() + 1);
                std::thread::sleep(std::time::Duration::from_millis(ms));
                Ok(None)
            }
            Some(FaultKind::Crash) => {
                let seq = self.injected.get() + 1;
                self.injected.set(seq);
                // Injected worker death IS the tested behavior: the
                // serve worker thread dies here and the cluster
                // supervisor's catch_unwind boundary owns the payload.
                // curlint: allow(panic) -- deterministic crash injection; payload caught at the supervisor boundary
                std::panic::panic_any(InjectedCrash { site, seq });
            }
            Some(kind) => {
                self.injected.set(self.injected.get() + 1);
                Ok(Some(kind))
            }
        }
    }

    /// Overwrite one element of one row of `t` with the poison value.
    /// Row-confined on purpose: row-independent kernels then corrupt
    /// exactly one slot's stream, which serve must fail individually.
    fn poison(&self, t: &mut Tensor, kind: FaultKind) -> Result<()> {
        let val = if kind == FaultKind::Nan { f32::NAN } else { f32::INFINITY };
        let rows = t.shape.first().copied().unwrap_or(1).max(1);
        let data = t.f32s_mut()?;
        let per = (data.len() / rows).max(1);
        let mut rng = self.rng.borrow_mut();
        let idx = rng.below(rows) * per + rng.below(per);
        if let Some(x) = data.get_mut(idx) {
            *x = val;
        }
        Ok(())
    }

    fn run_poisoned<F>(&self, site: FaultSite, call: F) -> Result<Tensor>
    where
        F: FnOnce() -> Result<Tensor>,
    {
        let armed = self.pre(site)?;
        let mut out = call()?;
        if let Some(kind) = armed {
            self.poison(&mut out, kind)?;
        }
        Ok(out)
    }
}

impl Backend for FaultyBackend {
    fn name(&self) -> &'static str {
        "faulty"
    }

    fn manifest(&self) -> &Json {
        self.inner.manifest()
    }

    fn exec_count(&self) -> u64 {
        self.inner.exec_count()
    }

    fn embed(&self, cfg: &ModelConfig, emb: &Tensor, tokens: &Tensor) -> Result<Tensor> {
        self.inner.embed(cfg, emb, tokens)
    }

    fn layer_forward(&self, cfg: &ModelConfig, p: &LayerParams, x: &Tensor) -> Result<Tensor> {
        self.inner.layer_forward(cfg, p, x)
    }

    fn layer_forward_infer(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
    ) -> Result<Tensor> {
        self.inner.layer_forward_infer(cfg, p, x)
    }

    fn supports_kv_decode(&self) -> bool {
        self.inner.supports_kv_decode()
    }

    fn fixed_shape(&self) -> bool {
        self.inner.fixed_shape()
    }

    fn layer_prefill(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
        kv: &mut KvCache,
        layer: usize,
        slot: usize,
    ) -> Result<Tensor> {
        self.run_poisoned(FaultSite::Prefill, || {
            self.inner.layer_prefill(cfg, p, x, kv, layer, slot)
        })
    }

    fn layer_decode_batch(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
        kv: &mut KvCache,
        layer: usize,
        slots: &[usize],
    ) -> Result<Tensor> {
        self.run_poisoned(FaultSite::Decode, || {
            self.inner.layer_decode_batch(cfg, p, x, kv, layer, slots)
        })
    }

    fn compress_kv_slot(&self, cfg: &ModelConfig, kv: &mut KvCache, slot: usize) -> Result<usize> {
        // No f32 output to poison here: any armed non-delay kind fails
        // the call (a corrupt compaction is indistinguishable from a
        // failed one at this seam).
        match self.arm(FaultSite::Compress) {
            Some(FaultKind::Delay(ms)) => {
                self.injected.set(self.injected.get() + 1);
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
            Some(FaultKind::Crash) => {
                let seq = self.injected.get() + 1;
                self.injected.set(seq);
                // curlint: allow(panic) -- deterministic crash injection; payload caught at the supervisor boundary
                std::panic::panic_any(InjectedCrash { site: FaultSite::Compress, seq });
            }
            Some(_) => return Err(self.fault_err(FaultSite::Compress)),
            None => {}
        }
        self.inner.compress_kv_slot(cfg, kv, slot)
    }

    fn pack_head(&self, emb: &Tensor) -> Result<Option<PackedHead>> {
        self.inner.pack_head(emb)
    }

    fn head_logits_packed(
        &self,
        cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        packed: &PackedHead,
    ) -> Result<Tensor> {
        self.run_poisoned(FaultSite::Head, || {
            self.inner.head_logits_packed(cfg, x, ln_f, packed)
        })
    }

    fn layer_forward_calib(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
    ) -> Result<CalibOut> {
        self.inner.layer_forward_calib(cfg, p, x)
    }

    fn head_logits(
        &self,
        cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        emb: &Tensor,
    ) -> Result<Tensor> {
        self.run_poisoned(FaultSite::Head, || self.inner.head_logits(cfg, x, ln_f, emb))
    }

    fn head_nll(
        &self,
        cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        emb: &Tensor,
        targets: &Tensor,
    ) -> Result<Tensor> {
        self.run_poisoned(FaultSite::Head, || self.inner.head_nll(cfg, x, ln_f, emb, targets))
    }

    fn train_step(
        &self,
        cfg: &ModelConfig,
        store: &mut TensorStore,
        opt: &mut TensorStore,
        tokens: &Tensor,
        targets: &Tensor,
        lr: f32,
        t: f32,
    ) -> Result<f64> {
        self.inner.train_step(cfg, store, opt, tokens, targets, lr, t)
    }

    fn heal_step(
        &self,
        cfg: &ModelConfig,
        student: &mut TensorStore,
        opt: &mut TensorStore,
        layer: usize,
        x: &Tensor,
        y_teacher: &Tensor,
        lr: f32,
        t: f32,
    ) -> Result<HealOut> {
        self.inner.heal_step(cfg, student, opt, layer, x, y_teacher, lr, t)
    }

    #[allow(clippy::too_many_arguments)]
    fn switched_step(
        &self,
        cfg: &ModelConfig,
        teacher: &TensorStore,
        student: &mut TensorStore,
        adapters: &mut TensorStore,
        opt: &mut TensorStore,
        adapter: crate::peft::Adapter,
        mode: StepMode,
        tokens: &Tensor,
        targets: &Tensor,
        loss_mask: Option<&Tensor>,
        lr: f32,
        t: f32,
    ) -> Result<f64> {
        self.inner.switched_step(
            cfg, teacher, student, adapters, opt, adapter, mode, tokens, targets, loss_mask,
            lr, t,
        )
    }

    fn switched_logits(
        &self,
        cfg: &ModelConfig,
        teacher: &TensorStore,
        student: &TensorStore,
        adapters: &TensorStore,
        adapter: crate::peft::Adapter,
        tokens: &Tensor,
    ) -> Result<Tensor> {
        self.inner.switched_logits(cfg, teacher, student, adapters, adapter, tokens)
    }

    fn supports_artifacts(&self) -> bool {
        self.inner.supports_artifacts()
    }

    fn artifact_names(&self) -> Vec<String> {
        self.inner.artifact_names()
    }

    fn artifact_spec(&self, name: &str) -> Result<ArtifactSpec> {
        self.inner.artifact_spec(name)
    }

    fn execute_artifact(&self, name: &str, bindings: &Bindings) -> Result<HashMap<String, Tensor>> {
        self.inner.execute_artifact(name, bindings)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parse_grammar() {
        let p = FaultPlan::parse("seed=7;decode=0.05;head=0.01:nan;prefill=0.02:delay5").unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.rules.len(), 3);
        assert_eq!(
            p.rules[0],
            FaultRule { site: FaultSite::Decode, p: 0.05, kind: FaultKind::Error }
        );
        assert_eq!(p.rules[1].kind, FaultKind::Nan);
        assert_eq!(p.rules[2].kind, FaultKind::Delay(5));
        // `all=` expands to one rule per site.
        let p = FaultPlan::parse("all=0.5:inf").unwrap();
        assert_eq!(p.rules.len(), 4);
        assert_eq!(p.seed, 0);
        // Round-trip through Display.
        let p2 = FaultPlan::parse(&p.to_string()).unwrap();
        assert_eq!(p, p2);
    }

    #[test]
    fn plan_parse_rejects_garbage() {
        for bad in [
            "",
            "decode",
            "decode=1.5",
            "decode=-0.1",
            "decode=0.5:boom",
            "warp=0.5",
            "seed=x;decode=0.1",
            "decode=0.1:delayx",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec '{bad}' should be rejected");
        }
    }

    #[test]
    fn injected_fault_downcasts() {
        let plan = FaultPlan::parse("decode=1.0").unwrap();
        let fb = FaultyBackend::new(
            Box::new(crate::backend::native::NativeBackend::new()),
            plan,
        );
        let err = fb.fault_err(FaultSite::Decode);
        let inj = err.downcast_ref::<InjectedFault>().unwrap();
        assert_eq!(inj.site, FaultSite::Decode);
        assert_eq!(inj.seq, 1);
        assert_eq!(fb.injected(), 1);
    }
}

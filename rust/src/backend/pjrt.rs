//! PJRT artifact backend (feature `pjrt`): loads AOT HLO-text artifacts
//! and executes them via the `xla` crate.
//!
//! Wraps `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `compile` → `execute`. The manifest written by `python/compile/aot.py`
//! drives generic marshalling: artifacts declare named, shaped
//! inputs/outputs, and callers bind tensors by name — the backend
//! validates shapes/dtypes and fixes positional order.
//!
//! Interchange is HLO **text**: xla_extension 0.5.1 rejects jax>=0.5
//! serialized protos (64-bit instruction ids); the text parser reassigns
//! ids.
//!
//! The typed [`Backend`] operations map one-to-one onto the artifact
//! naming scheme (`{config}_embed_fwd`, `{config}_layer_fwd_dense`,
//! `{config}_layer_fwd_cured_r{rank}_c{combo}`, …); the generic
//! `execute_artifact` passthrough additionally serves the switched
//! full-model graphs of the PEFT comparisons.

use crate::backend::{
    is_adapter_param, is_cur_param, layer_of, Backend, CalibOut, HealOut, LayerParams, Proj,
    StepMode,
};
use crate::model::ModelConfig;
use crate::peft::Adapter;
use crate::runtime::{spec_from_manifest, ArtifactSpec, Bindings};
use crate::tensor::{Data, DType, Tensor, TensorStore};
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A compiled artifact plus its spec.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT backend: client + manifest + executable cache.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Json,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    execs: Cell<u64>,
}

impl PjrtBackend {
    /// Open the artifacts directory (must contain `manifest.json`).
    pub fn new(artifacts_dir: &Path) -> Result<PjrtBackend> {
        let mpath = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("missing {} — run `make artifacts`", mpath.display()))?;
        let manifest = Json::parse(&text)?;
        // curlint: allow(typed-error) -- wraps the foreign xla error's debug string; the feature-gated pjrt backend has no typed taxonomy yet
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(PjrtBackend {
            client,
            dir: artifacts_dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
            execs: Cell::new(0),
        })
    }

    pub fn cached_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Load + compile an artifact (cached).
    fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = spec_from_manifest(&self.manifest, name)?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse hlo {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let exec = Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    fn execute(&self, name: &str, bindings: &Bindings) -> Result<HashMap<String, Tensor>> {
        let exe = self.load(name)?;
        let lits = self.marshal_inputs(&exe.spec, bindings)?;
        let outs = exe
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e:?}", exe.spec.name))?;
        self.execs.set(self.execs.get() + 1);
        let result = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {}: {e:?}", exe.spec.name))?;
        let pieces = result
            .to_tuple()
            .map_err(|e| anyhow!("untuple {}: {e:?}", exe.spec.name))?;
        if pieces.len() != exe.spec.outputs.len() {
            bail!(
                "artifact {} returned {} outputs, manifest says {}",
                exe.spec.name,
                pieces.len(),
                exe.spec.outputs.len()
            );
        }
        let mut out = HashMap::new();
        for (io, lit) in exe.spec.outputs.iter().zip(pieces) {
            let t = match io.dtype {
                DType::F32 => {
                    let v =
                        lit.to_vec::<f32>().map_err(|e| anyhow!("literal to f32 vec: {e:?}"))?;
                    Tensor::from_f32(&io.shape, v)
                }
                DType::I32 => {
                    let v =
                        lit.to_vec::<i32>().map_err(|e| anyhow!("literal to i32 vec: {e:?}"))?;
                    Tensor::from_i32(&io.shape, v)
                }
            };
            out.insert(io.name.clone(), t);
        }
        Ok(out)
    }

    fn marshal_inputs(&self, spec: &ArtifactSpec, bindings: &Bindings) -> Result<Vec<xla::Literal>> {
        let mut lits = Vec::with_capacity(spec.inputs.len());
        for io in &spec.inputs {
            let t = bindings
                .get(&io.name)
                .ok_or_else(|| anyhow!("artifact {}: missing input '{}'", spec.name, io.name))?;
            if t.shape != io.shape {
                bail!(
                    "artifact {}: input '{}' shape {:?} != expected {:?}",
                    spec.name,
                    io.name,
                    t.shape,
                    io.shape
                );
            }
            if t.dtype() != io.dtype {
                bail!(
                    "artifact {}: input '{}' dtype {:?} != expected {:?}",
                    spec.name,
                    io.name,
                    t.dtype(),
                    io.dtype
                );
            }
            lits.push(tensor_to_literal(t)?);
        }
        Ok(lits)
    }

    fn take(outs: &mut HashMap<String, Tensor>, key: &str, what: &str) -> Result<Tensor> {
        outs.remove(key).with_context(|| format!("{what} output '{key}' missing"))
    }
}

/// Resolve one switched-artifact weight input by name, strictly:
///
/// * tensors of the **active** adapter family must exist in the adapter
///   store — a missing (e.g. misnamed) one is a hard error, because
///   zero-filling it would silently evaluate/train the base model;
/// * inactive-family adapter tensors bind zeros (their graph switch is
///   off, the values are inert);
/// * CUR student factors (`c_`/`u_`/`du_`/`r_`) of a **cured** layer
///   must exist in the student store — hard error otherwise; factors of
///   non-cured layers bind zeros (that layer's switch is 0);
/// * everything else is a dense teacher tensor (always required).
fn resolve_switched_input(
    name: &str,
    shape: &[usize],
    teacher: &TensorStore,
    student: &TensorStore,
    adapters: &TensorStore,
    adapter: Adapter,
    cured: &[usize],
) -> Result<Tensor> {
    let suffix = name.split('.').next_back().unwrap_or("");
    if is_adapter_param(name) {
        if Adapter::family_of_suffix(suffix) == Some(adapter) {
            return Ok(adapters
                .get(name)
                .with_context(|| {
                    format!(
                        "switched graph input '{name}' belongs to the active adapter \
                         '{}' but is missing from the adapter store — refusing to \
                         silently bind zeros",
                        adapter.label()
                    )
                })?
                .clone());
        }
        return Ok(Tensor::zeros(shape));
    }
    if is_cur_param(name) {
        if layer_of(name).map(|l| cured.contains(&l)).unwrap_or(false) {
            return Ok(student
                .get(name)
                .with_context(|| {
                    format!(
                        "switched graph input '{name}' is a cured layer's factor but \
                         is missing from the student store — refusing to silently \
                         bind zeros"
                    )
                })?
                .clone());
        }
        return Ok(Tensor::zeros(shape));
    }
    Ok(teacher.get(name)?.clone())
}

/// Map a [`LayerParams`] view onto the artifact's `L.*` input names.
/// Returns the (rank, combo) signature when any projection is cured.
fn bind_layer_params<'b>(
    b: &mut Bindings<'b>,
    p: &'b LayerParams<'b>,
) -> Result<Option<(usize, String)>> {
    b.bind_mut("L.ln1", p.ln1);
    b.bind_mut("L.ln2", p.ln2);
    b.bind_mut("L.w_v", p.v);
    b.bind_mut("L.w_o", p.o);
    b.bind_mut("L.w_up", p.up);
    b.bind_mut("L.w_down", p.down);
    let mut rank = None;
    let mut cured = [false; 3];
    for (i, (name, proj)) in
        [("q", &p.q), ("k", &p.k), ("gate", &p.gate)].into_iter().enumerate()
    {
        match proj {
            Proj::Dense(w) => b.bind_mut(format!("L.w_{name}"), w),
            Proj::Cured { c, u, r } => {
                cured[i] = true;
                rank = r.shape.first().copied();
                b.bind_mut(format!("L.c_{name}"), *c);
                b.bind_mut(format!("L.r_{name}"), *r);
                b.bind_owned(format!("L.u_{name}"), u.as_ref().clone());
            }
        }
    }
    match (cured, rank) {
        ([false, false, false], _) => Ok(None),
        (_, Some(rank)) => {
            let combo = match cured {
                [true, true, true] => "all",
                [true, true, false] => "qk",
                [true, false, true] => "qg",
                [false, true, true] => "kg",
                [false, false, true] => "gate",
                other => bail!("no AOT artifact for cured-projection set {other:?}"),
            };
            Ok(Some((rank, combo.to_string())))
        }
        _ => bail!("cured projection without a rank"),
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Json {
        &self.manifest
    }

    fn exec_count(&self) -> u64 {
        self.execs.get()
    }

    fn embed(&self, cfg: &ModelConfig, emb: &Tensor, tokens: &Tensor) -> Result<Tensor> {
        let mut out = self.execute(
            &format!("{}_embed_fwd", cfg.name),
            &Bindings::new().bind("tokens", tokens).bind("emb", emb),
        )?;
        Self::take(&mut out, "x", "embed")
    }

    fn layer_forward(&self, cfg: &ModelConfig, p: &LayerParams, x: &Tensor) -> Result<Tensor> {
        let mut b = Bindings::new().bind("x", x);
        let art = match bind_layer_params(&mut b, p)? {
            None => format!("{}_layer_fwd_dense", cfg.name),
            Some((rank, combo)) => {
                format!("{}_layer_fwd_cured_r{rank}_c{combo}", cfg.name)
            }
        };
        let mut out = self.execute(&art, &b)?;
        Self::take(&mut out, "y", "layer")
    }

    fn layer_forward_infer(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
    ) -> Result<Tensor> {
        // The AOT forward graphs are already inference-only — no backward
        // cache escapes an artifact — so the plain forward IS the infer
        // path here. KV-cached decode (layer_prefill/layer_decode_batch)
        // and the packed LM head (pack_head/head_logits_packed) stay at
        // their bailing trait defaults: the lowered artifacts are
        // fixed-shape full-window graphs, so generation falls back to
        // the windowed full-recompute loop and the generation server's
        // continuous-batching slots are unavailable (scoring mode still
        // works). Lowering a single-position decode graph per layer is
        // the natural follow-up once the ring cache layout settles.
        self.layer_forward(cfg, p, x)
    }

    fn layer_forward_calib(
        &self,
        cfg: &ModelConfig,
        p: &LayerParams,
        x: &Tensor,
    ) -> Result<CalibOut> {
        let mut b = Bindings::new().bind("x", x);
        if bind_layer_params(&mut b, p)?.is_some() {
            bail!("calibration runs on the dense model only");
        }
        let mut out = self.execute(&format!("{}_layer_fwd_calib", cfg.name), &b)?;
        Ok(CalibOut {
            y: Self::take(&mut out, "y", "calib")?,
            attn_sumsq: Self::take(&mut out, "attn_sumsq", "calib")?,
            ffn_sumsq: Self::take(&mut out, "ffn_sumsq", "calib")?,
            attn_in: Self::take(&mut out, "attn_in", "calib")?,
            ffn_in: Self::take(&mut out, "ffn_in", "calib")?,
        })
    }

    fn head_logits(
        &self,
        cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        emb: &Tensor,
    ) -> Result<Tensor> {
        let mut out = self.execute(
            &format!("{}_head_logits", cfg.name),
            &Bindings::new().bind("x", x).bind("ln_f", ln_f).bind("emb", emb),
        )?;
        Self::take(&mut out, "logits", "head")
    }

    fn head_nll(
        &self,
        cfg: &ModelConfig,
        x: &Tensor,
        ln_f: &Tensor,
        emb: &Tensor,
        targets: &Tensor,
    ) -> Result<Tensor> {
        let mut out = self.execute(
            &format!("{}_head_nll", cfg.name),
            &Bindings::new()
                .bind("x", x)
                .bind("ln_f", ln_f)
                .bind("emb", emb)
                .bind("targets", targets),
        )?;
        Self::take(&mut out, "nll", "head")
    }

    fn train_step(
        &self,
        cfg: &ModelConfig,
        store: &mut TensorStore,
        opt: &mut TensorStore,
        tokens: &Tensor,
        targets: &Tensor,
        lr: f32,
        t: f32,
    ) -> Result<f64> {
        let names = cfg.dense_param_names();
        let art = format!("{}_train_step_dense", cfg.name);
        // Seed missing optimizer moments first: the bindings below hold
        // borrows of `opt`, so all insertions must happen up front.
        for n in &names {
            for k in ["m", "v"] {
                let key = format!("{k}.{n}");
                if !opt.contains(&key) {
                    let shape = store.get(n)?.shape.clone();
                    opt.insert(key, Tensor::zeros(&shape));
                }
            }
        }
        let mut b = Bindings::new().bind("tokens", tokens).bind("targets", targets);
        b.bind_owned("lr", Tensor::scalar_f32(lr));
        b.bind_owned("t", Tensor::scalar_f32(t));
        for n in &names {
            b.bind_mut(n.clone(), store.get(n)?);
            for k in ["m", "v"] {
                let key = format!("{k}.{n}");
                b.bind_mut(key.clone(), opt.get(&key)?);
            }
        }
        let mut out = self.execute(&art, &b)?;
        drop(b);
        let loss = Self::take(&mut out, "loss", "train step")?.f32s()?[0] as f64;
        for n in &names {
            store.insert(n.clone(), Self::take(&mut out, n, "train step")?);
            for k in ["m", "v"] {
                let key = format!("{k}.{n}");
                opt.insert(key.clone(), Self::take(&mut out, &key, "train step")?);
            }
        }
        Ok(loss)
    }

    fn heal_step(
        &self,
        cfg: &ModelConfig,
        student: &mut TensorStore,
        opt: &mut TensorStore,
        layer: usize,
        x: &Tensor,
        y_teacher: &Tensor,
        lr: f32,
        t: f32,
    ) -> Result<HealOut> {
        // The per-layer heal artifact is lowered for combo=all at the
        // rank-rule rank; verify the store matches.
        let tr = ["du_q", "du_k", "du_gate"];
        for proj in ["q", "k", "gate"] {
            if !student.contains(&format!("L{layer}.c_{proj}")) {
                bail!("heal artifact requires combo=all (layer {layer} missing c_{proj})");
            }
        }
        let rank = student.get(&format!("L{layer}.u_q"))?.shape[0];
        let art = format!("{}_layer_heal_step_r{rank}", cfg.name);
        let mut b = Bindings::new().bind("x", x).bind("y_teacher", y_teacher);
        b.bind_owned("lr", Tensor::scalar_f32(lr));
        b.bind_owned("t", Tensor::scalar_f32(t));
        for suffix in ["ln1", "ln2", "w_v", "w_o", "w_up", "w_down"] {
            b.bind_mut(format!("L.{suffix}"), student.get(&format!("L{layer}.{suffix}"))?);
        }
        for proj in ["q", "k", "gate"] {
            for part in ["c", "u", "du", "r"] {
                b.bind_mut(
                    format!("L.{part}_{proj}"),
                    student.get(&format!("L{layer}.{part}_{proj}"))?,
                );
            }
        }
        for name in tr {
            for kind in ["m", "v"] {
                let key = format!("heal.L{layer}.{kind}.{name}");
                if !opt.contains(&key) {
                    opt.insert(key.clone(), Tensor::zeros(&[rank, rank]));
                }
                b.bind_owned(format!("{kind}.{name}"), opt.get(&key)?.clone());
            }
        }
        let mut out = self.execute(&art, &b)?;
        drop(b);
        let loss = Self::take(&mut out, "loss", "heal step")?.f32s()?[0] as f64;
        let y_student = Self::take(&mut out, "y_student", "heal step")?;
        for name in tr {
            let proj = name
                .strip_prefix("du_")
                .ok_or_else(|| anyhow!("trainable tensor '{name}' missing du_ prefix"))?;
            student.insert(
                format!("L{layer}.du_{proj}"),
                Self::take(&mut out, name, "heal step")?,
            );
            for kind in ["m", "v"] {
                opt.insert(
                    format!("heal.L{layer}.{kind}.{name}"),
                    Self::take(&mut out, &format!("{kind}.{name}"), "heal step")?,
                );
            }
        }
        Ok(HealOut { loss, y_student })
    }

    fn switched_step(
        &self,
        cfg: &ModelConfig,
        teacher: &TensorStore,
        student: &mut TensorStore,
        adapters: &mut TensorStore,
        opt: &mut TensorStore,
        adapter: Adapter,
        mode: StepMode,
        tokens: &Tensor,
        targets: &Tensor,
        loss_mask: Option<&Tensor>,
        lr: f32,
        t: f32,
    ) -> Result<f64> {
        let art = format!("{}_{}_{}", cfg.name, mode.artifact_stem(), adapter.tag());
        let spec = self.artifact_spec(&art)?;
        let switches = crate::heal::SwitchedRunner::switches(cfg, student);
        let cured = crate::compress::cured_layers_of(student);
        let tag = adapter.tag();
        // Seed missing optimizer moments up front (the bindings below
        // hold borrows of `opt`).
        for io in &spec.inputs {
            if let Some(rest) =
                io.name.strip_prefix("m.").or_else(|| io.name.strip_prefix("v."))
            {
                let kind = &io.name[..1];
                let key = format!("{tag}.{kind}.{rest}");
                if !opt.contains(&key) {
                    opt.insert(key, Tensor::zeros(&io.shape));
                }
            }
        }
        let mut b = Bindings::new()
            .bind("tokens", tokens)
            .bind("targets", targets)
            .bind("switches", &switches);
        b.bind_owned("lr", Tensor::scalar_f32(lr));
        b.bind_owned("t", Tensor::scalar_f32(t));
        if let Some(m) = loss_mask {
            b.bind_mut("loss_mask", m);
        }
        for io in &spec.inputs {
            if b.get(&io.name).is_some() {
                continue;
            }
            let name = &io.name;
            if let Some(rest) = name.strip_prefix("m.").or_else(|| name.strip_prefix("v."))
            {
                let kind = &name[..1];
                b.bind_mut(name.clone(), opt.get(&format!("{tag}.{kind}.{rest}"))?);
            } else {
                b.bind_owned(
                    name.clone(),
                    resolve_switched_input(
                        name, &io.shape, teacher, student, adapters, adapter, &cured,
                    )?,
                );
            }
        }
        let mut out = self.execute(&art, &b)?;
        drop(b);
        let loss = Self::take(&mut out, "loss", "switched step")?.f32s()?[0] as f64;
        for o in &spec.outputs {
            if o.name == "loss" {
                continue;
            }
            let tensor = out.remove(&o.name).context("missing switched-step output")?;
            if let Some(rest) =
                o.name.strip_prefix("m.").or_else(|| o.name.strip_prefix("v."))
            {
                let kind = &o.name[..1];
                opt.insert(format!("{tag}.{kind}.{rest}"), tensor);
            } else if is_adapter_param(&o.name) {
                adapters.insert(o.name.clone(), tensor);
            } else if student.contains(&o.name) {
                // du_* updates belong to the student (only written for
                // layers that are actually cured — zeros stay zeros, and
                // writing them into the student store for non-cured
                // layers would pollute it).
                student.insert(o.name.clone(), tensor);
            }
        }
        Ok(loss)
    }

    fn switched_logits(
        &self,
        cfg: &ModelConfig,
        teacher: &TensorStore,
        student: &TensorStore,
        adapters: &TensorStore,
        adapter: Adapter,
        tokens: &Tensor,
    ) -> Result<Tensor> {
        let art = format!("{}_model_logits_switched_{}", cfg.name, adapter.tag());
        let spec = self.artifact_spec(&art)?;
        let switches = crate::heal::SwitchedRunner::switches(cfg, student);
        let cured = crate::compress::cured_layers_of(student);
        // The lowered signature includes unused `targets`; bind zeros.
        let dummy_targets =
            Tensor::from_i32(&[cfg.batch, cfg.seq], vec![0; cfg.batch * cfg.seq]);
        let mut b = Bindings::new().bind("tokens", tokens).bind("switches", &switches);
        b.bind_mut("targets", &dummy_targets);
        for io in &spec.inputs {
            if b.get(&io.name).is_some() {
                continue;
            }
            b.bind_owned(
                io.name.clone(),
                resolve_switched_input(
                    &io.name, &io.shape, teacher, student, adapters, adapter, &cured,
                )?,
            );
        }
        let mut out = self.execute(&art, &b)?;
        Self::take(&mut out, "logits", "switched logits")
    }

    fn supports_artifacts(&self) -> bool {
        true
    }

    fn artifact_names(&self) -> Vec<String> {
        self.manifest
            .at(&["artifacts"])
            .and_then(|a| a.as_obj())
            .map(|o| o.iter().map(|(k, _)| k.to_string()).collect())
            .unwrap_or_default()
    }

    fn artifact_spec(&self, name: &str) -> Result<ArtifactSpec> {
        spec_from_manifest(&self.manifest, name)
    }

    fn execute_artifact(
        &self,
        name: &str,
        bindings: &Bindings,
    ) -> Result<HashMap<String, Tensor>> {
        self.execute(name, bindings)
    }
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    // Single-copy path: build the literal directly from raw host bytes.
    // (The obvious `Literal::vec1(..).reshape(..)` costs two extra full
    // copies per argument — measured 1.32x end-to-end on the pretrain
    // step, see EXPERIMENTS.md §Perf.)
    let (ty, bytes): (xla::ElementType, &[u8]) = match &t.data {
        Data::F32(v) => (xla::ElementType::F32, pod_bytes(v)),
        Data::I32(v) => (xla::ElementType::S32, pod_bytes(v)),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, bytes)
        // curlint: allow(typed-error) -- wraps the foreign xla error's debug string; the feature-gated pjrt backend has no typed taxonomy yet
        .map_err(|e| anyhow!("create literal: {e:?}"))
}

/// Numeric element types whose host slices may be reinterpreted as raw
/// bytes for literal marshalling. Sealed: implemented only for `f32` and
/// `i32` — plain 4-byte numerics with no padding, no niches, and every
/// bit pattern valid when read back as `u8`.
trait PodNum: sealed::Sealed {}
impl PodNum for f32 {}
impl PodNum for i32 {}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

// PJRT untyped-literal ingestion expects little-endian element bytes;
// this gate keeps `pod_bytes` from silently producing byte-swapped
// literals on a big-endian host.
#[cfg(not(target_endian = "little"))]
compile_error!("PJRT literal marshalling assumes little-endian host bytes");

/// View a numeric slice as its underlying bytes, in host memory order.
fn pod_bytes<T: PodNum>(v: &[T]) -> &[u8] {
    // `T` is sealed to f32/i32 — 4-byte POD numerics with no padding or
    // invalid bit patterns, so every element is readable as plain bytes.
    // SAFETY: the pointer comes from a live `&[T]`, alignment only
    // shrinks (align_of::<u8>() == 1), the length scales by the element
    // size, and the borrow ties the byte view's lifetime to `v`.
    unsafe {
        std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
    }
}

#[cfg(test)]
mod tests {
    use super::pod_bytes;

    // These run under Miri in CI (the `miri` lane): the raw-pointer
    // reinterpretation above is the repo's only unsafe block, and Miri
    // checks the provenance/alignment argument the SAFETY comment makes.
    #[test]
    fn pod_bytes_views_f32_in_host_order() {
        let v = [1.0f32, -2.5, f32::NAN, 0.0];
        let b = pod_bytes(&v);
        assert_eq!(b.len(), std::mem::size_of_val(&v[..]));
        for (i, x) in v.iter().enumerate() {
            assert_eq!(&b[i * 4..(i + 1) * 4], x.to_ne_bytes());
        }
    }

    #[test]
    fn pod_bytes_views_i32_and_empty_slices() {
        let v = [i32::MIN, -1, 0, i32::MAX];
        let b = pod_bytes(&v);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(&b[i * 4..(i + 1) * 4], x.to_ne_bytes());
        }
        let empty: [f32; 0] = [];
        assert!(pod_bytes(&empty).is_empty());
    }
}

//! `curing` — CLI for the CURing compression system.
//!
//! Commands (see `curing help`):
//!   pretrain   train the dense "original" model (cached)
//!   calibrate  run WANDA/angular-distance calibration
//!   compress   CURing-compress k layers and evaluate
//!   heal       layer-wise KD healing of a cured model
//!   eval       evaluate a stored model on the Figure-4 suite
//!   serve      run the batching eval server demo
//!   info       artifact/manifest inventory

use anyhow::{bail, Result};
use curing::backend::KvPolicy;
use curing::compress::{CompressOptions, LayerStrategy};
use curing::coordinator::{default_pretrain_steps, Ctx, EvalSizes};
use curing::data::{Corpus, CorpusKind, SEED_HEAL};
use curing::heal::{heal_layers, HealOptions, StepMode, SwitchedRunner};
use curing::peft::{init_adapters, trainable_params, Adapter};
use curing::pipeline::LayerPlan;
use curing::serve::{
    drain_gen_responses, drain_score_responses, spawn_gen_clients, spawn_score_clients,
    ClusterServer, GenerationServer, Request,
};
use curing::tensor::TensorStore;
use curing::util::cli::Args;
use curing::util::stats::mib;
use curing::wanda::Selector;
use std::time::Duration;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let cmd = args.command.clone().unwrap_or_else(|| "help".to_string());
    match cmd.as_str() {
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        "info" => info(args),
        "pretrain" => pretrain(args),
        "calibrate" => calibrate(args),
        "compress" => compress(args),
        "heal" => heal(args),
        "peft" => peft(args),
        "eval" => eval(args),
        "generate" => generate(args),
        "serve" => serve(args),
        other => bail!("unknown command '{other}' (try `curing help`)"),
    }
}

fn print_help() {
    println!(
        "curing — LLM compression via DEIM-CUR decomposition (ICML 2025 reproduction)

USAGE: curing <command> [--flags]

COMMANDS
  info                         list artifacts and configs
  pretrain  --config tiny --steps N          train + cache the dense model
  calibrate --config tiny --examples 128     WANDA + angular distances
  compress  --config tiny --layers K [--rank 16] [--combo all]
            [--selector curing] [--strategy angular] [--eval]
  heal      --config tiny --layers K --steps N [--rank 16]
  peft      --adapter du|lora|mora|curlora [--mode heal|task] [--layers K]
            [--steps N] [--lr 1e-3]        full-model switched steps
            (heal: 0.9·KD(T=10) + 0.1·CE vs the dense teacher; task:
             answer-masked CE on synth-mrpc) — native, no artifacts
  eval      --config tiny [--layers K]       Figure-4 metric suite
  generate  --prompt \"the atom\" [--layers K] [--tokens 24]  greedy decode
  serve     --config tiny [--mode score|generate|mixed] [--clients 4]
            [--requests 32] [--slots 4] [--tokens 24] [--prompt-len 8]
            [--kv-policy exact|cur:<keep>[:<sinks>:<recent>]]
            [--deadline-ms 0] per-request deadline (0 = none)
            [--queue-cap 0]   backlog bound, sheds Overloaded (0 = unbounded)
            [--workers 1]     replicated engines behind the cluster router
            [--retry-budget 2]  replays per request after a worker death
            [--heartbeat-ms 200] hung-worker liveness deadline
            [--faults \"seed=7;decode=0.05;head=0.01:nan;prefill=0.02:crash\"]

ENV  CURING_BACKEND (native|pjrt; default: pjrt when built in and artifacts exist)
     CURING_ARTIFACTS (default ./artifacts)   CURING_RUNDIR (default ./runs)
     CURING_PRETRAIN_STEPS (default 400)      CURING_THREADS (native matmul workers)
     CURING_NO_KV_CACHE=1 (force the cache-free replay reference in `generate`)
     CURING_FAULTS (fault-injection plan wrapped around any command's backend)"
    );
}

fn info(_args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    println!("backend: {}", ctx.rt.backend_name());
    println!("configs:");
    if let Some(configs) = ctx.rt.manifest().at(&["configs"]).and_then(|c| c.as_obj()) {
        for (name, _) in configs.iter() {
            let cfg = curing::model::ModelConfig::from_manifest(ctx.rt.manifest(), name)?;
            println!(
                "  {:<8} d_model {:>4}  layers {:>2}  heads {:>2}  d_inter {:>4}  seq {:>4}  batch {:>3}",
                name, cfg.d_model, cfg.n_layers, cfg.n_heads, cfg.d_inter, cfg.seq, cfg.batch
            );
        }
    }
    if ctx.rt.supports_artifacts() {
        println!("artifacts:");
        for name in ctx.rt.artifact_names() {
            let spec = ctx.rt.spec(&name)?;
            println!("  {:<44} {:>3} in / {:>3} out", name, spec.inputs.len(), spec.outputs.len());
        }
    } else {
        println!("artifacts: none (the native backend executes layers directly)");
    }
    Ok(())
}

fn pretrain(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let config = args.str_opt("config", "tiny");
    let steps = args.usize_opt("steps", default_pretrain_steps());
    check_unknown(args)?;
    let store = ctx.load_or_pretrain(&config, steps)?;
    println!(
        "dense model ready: {} params ({:.1} MiB f32)",
        store.total_params(),
        mib(store.total_bytes() as f64)
    );
    Ok(())
}

fn calibrate(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let config = args.str_opt("config", "tiny");
    let examples = args.usize_opt("examples", 128);
    let steps = args.usize_opt("steps", default_pretrain_steps());
    check_unknown(args)?;
    let store = ctx.load_or_pretrain(&config, steps)?;
    let pipe = ctx.pipeline(&config)?;
    let calib = ctx.calibrate_cached(&pipe, &store, examples)?;
    println!("angular distances (layer: d(h_l-1, h_l)), ascending:");
    let mut order: Vec<usize> = pipe.cfg.middle_layers();
    order.sort_by(|&a, &b| calib.angular[a].total_cmp(&calib.angular[b]));
    for l in order {
        println!("  layer {:>2}: {:.4}", l, calib.angular[l]);
    }
    Ok(())
}

fn parse_opts(args: &Args) -> Result<CompressOptions> {
    Ok(CompressOptions {
        combo: args.str_opt("combo", "all"),
        r_max: args.usize_opt("rank", 16),
        selector: Selector::parse(&args.str_opt("selector", "curing"))?,
        seed: args.usize_opt("seed", 0) as u64,
    })
}

fn compress(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let config = args.str_opt("config", "tiny");
    let k = args.usize_opt("layers", 3);
    let steps = args.usize_opt("steps", default_pretrain_steps());
    let strategy = LayerStrategy::parse(&args.str_opt("strategy", "angular"))?;
    let opts = parse_opts(args)?;
    let do_eval = args.bool_flag("eval");
    check_unknown(args)?;
    let dense = ctx.load_or_pretrain(&config, steps)?;
    let pipe = ctx.pipeline(&config)?;
    let calib = ctx.calibrate_cached(&pipe, &dense, 128)?;
    let (student, plan, report) = ctx.compress_k(&pipe, &dense, &calib, k, strategy, &opts)?;
    println!(
        "compressed layers {:?} in {:.2}s, saved {:.2} MiB",
        report.layers,
        report.seconds_total,
        mib(report.bytes_saved() as f64)
    );
    let dir = curing::util::config::run_dir()
        .join("stores")
        .join(format!("{config}_cured_k{k}"));
    student.save(&dir)?;
    println!("cured store saved to {}", dir.display());
    if do_eval {
        let suite = ctx.eval_suite(&pipe, &student, &plan, &EvalSizes::default())?;
        println!("cured:  {}", suite.row());
        let dense_plan = LayerPlan::all_dense(&pipe.cfg);
        let suite0 = ctx.eval_suite(&pipe, &dense, &dense_plan, &EvalSizes::default())?;
        println!("dense:  {}", suite0.row());
    }
    Ok(())
}

fn heal(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let config = args.str_opt("config", "tiny");
    let k = args.usize_opt("layers", 3);
    let heal_steps = args.usize_opt("steps", 200);
    let pre_steps = args.usize_opt("pretrain-steps", default_pretrain_steps());
    let base_lr = args.f64_opt("lr", 3e-4);
    let opts = parse_opts(args)?;
    check_unknown(args)?;
    let dense = ctx.load_or_pretrain(&config, pre_steps)?;
    let pipe = ctx.pipeline(&config)?;
    let calib = ctx.calibrate_cached(&pipe, &dense, 128)?;
    let (mut student, plan, _) =
        ctx.compress_k(&pipe, &dense, &calib, k, LayerStrategy::Angular, &opts)?;
    let mut corpus = Corpus::new(CorpusKind::SynthC4, SEED_HEAL);
    let mut opt = TensorStore::new();
    let hopts = HealOptions { steps: heal_steps, base_lr, ..Default::default() };
    let hist = heal_layers(
        &pipe, &dense, &mut student, &mut opt, &ctx.vocab, &mut corpus, &hopts, 0,
    )?;
    for p in hist.iter().step_by((heal_steps / 10).max(1)) {
        println!("  heal step {:>4}: layer-MSE {:.6} (lr {:.2e})", p.step, p.loss, p.lr);
    }
    let suite = ctx.eval_suite(&pipe, &student, &plan, &EvalSizes::default())?;
    println!("healed: {}", suite.row());
    Ok(())
}

/// Full-model PEFT comparison driver (Figs 5–7 surface): compress k
/// layers, initialize the chosen adapter, run N switched steps through
/// the backend (native blended graphs by default), and report the loss
/// curve plus the switched model's wiki perplexity.
fn peft(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let config = args.str_opt("config", "tiny");
    let adapter = Adapter::parse(&args.str_opt("adapter", "du"))?;
    let mode_s = args.str_opt("mode", "heal");
    let mode = match mode_s.as_str() {
        "heal" => StepMode::Heal,
        "task" => StepMode::Task,
        other => bail!("unknown peft mode '{other}' (heal|task)"),
    };
    let k = args.usize_opt("layers", 3);
    let steps = args.usize_opt("steps", 30);
    let base_lr = args.f64_opt("lr", 1e-3);
    let pre_steps = args.usize_opt("pretrain-steps", default_pretrain_steps());
    let opts = parse_opts(args)?;
    check_unknown(args)?;
    let dense = ctx.load_or_pretrain(&config, pre_steps)?;
    let pipe = ctx.pipeline(&config)?;
    let calib = ctx.calibrate_cached(&pipe, &dense, 128)?;
    let (mut student, _plan, _) =
        ctx.compress_k(&pipe, &dense, &calib, k, LayerStrategy::Angular, &opts)?;
    let mut rng = curing::util::Rng::new(opts.seed.wrapping_add(17), 0);
    let mut adapters = init_adapters(adapter, &pipe.cfg, &dense, &calib, &mut rng)?;
    let mut opt = TensorStore::new();
    let runner = SwitchedRunner::new(adapter, mode);
    println!(
        "peft: adapter {} ({} trainable params), mode {mode_s}, k={k}, {steps} steps",
        adapter.label(),
        trainable_params(adapter, &pipe.cfg)?
    );
    let train_items: Vec<curing::data::TrainItem> = if mode == StepMode::Task {
        let mut trng = curing::util::Rng::new(77, 0);
        (0..64).map(|_| curing::data::mrpc_item(&ctx.vocab, &mut trng, pipe.cfg.seq).1).collect()
    } else {
        Vec::new()
    };
    let mut corpus = Corpus::new(CorpusKind::SynthC4, SEED_HEAL);
    for step in 0..steps {
        let lr = curing::heal::cosine_lr(step, steps, base_lr, steps / 5);
        let loss = match mode {
            StepMode::Heal => {
                let (toks, tgts) = corpus.batch(&ctx.vocab, pipe.cfg.batch, pipe.cfg.seq);
                let tokens =
                    curing::tensor::Tensor::from_i32(&[pipe.cfg.batch, pipe.cfg.seq], toks);
                let targets =
                    curing::tensor::Tensor::from_i32(&[pipe.cfg.batch, pipe.cfg.seq], tgts);
                runner.step(
                    &pipe, &dense, &mut student, &mut adapters, &mut opt, &tokens, &targets,
                    None, lr, step + 1,
                )?
            }
            StepMode::Task => {
                let (tokens, targets, mask) = curing::eval::pack_train(
                    &train_items,
                    step * pipe.cfg.batch,
                    pipe.cfg.batch,
                    pipe.cfg.seq,
                );
                runner.step(
                    &pipe, &dense, &mut student, &mut adapters, &mut opt, &tokens, &targets,
                    Some(&mask), lr, step + 1,
                )?
            }
        };
        if step % (steps / 10).max(1) == 0 || step + 1 == steps {
            println!("  step {step:>4}: loss {loss:.4} (lr {lr:.2e})");
        }
    }
    let mut wiki = Corpus::new(CorpusKind::SynthWiki, curing::data::SEED_EVAL);
    let ppl = curing::eval::perplexity_switched(
        &pipe, &dense, &student, &adapters, adapter, &ctx.vocab, &mut wiki, 4,
    )?;
    println!("switched wiki ppl after {steps} {mode_s} steps: {ppl:.2}");
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let config = args.str_opt("config", "tiny");
    let k = args.usize_opt("layers", 0);
    let steps = args.usize_opt("steps", default_pretrain_steps());
    let opts = parse_opts(args)?;
    check_unknown(args)?;
    let dense = ctx.load_or_pretrain(&config, steps)?;
    let pipe = ctx.pipeline(&config)?;
    if k == 0 {
        let suite =
            ctx.eval_suite(&pipe, &dense, &LayerPlan::all_dense(&pipe.cfg), &EvalSizes::default())?;
        println!("dense:  {}", suite.row());
    } else {
        let calib = ctx.calibrate_cached(&pipe, &dense, 128)?;
        let (student, plan, _) =
            ctx.compress_k(&pipe, &dense, &calib, k, LayerStrategy::Angular, &opts)?;
        let suite = ctx.eval_suite(&pipe, &student, &plan, &EvalSizes::default())?;
        println!("cured(k={k}): {}", suite.row());
    }
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let config = args.str_opt("config", "tiny");
    let prompt = args.str_opt("prompt", "the atom");
    let n_new = args.usize_opt("tokens", 24);
    let k = args.usize_opt("layers", 0);
    let steps = args.usize_opt("steps", default_pretrain_steps());
    let opts = parse_opts(args)?;
    check_unknown(args)?;
    let dense = ctx.load_or_pretrain(&config, steps)?;
    let pipe = ctx.pipeline(&config)?;
    let mut ids = vec![curing::data::vocab::BOS];
    ids.extend(ctx.vocab.encode(&prompt));
    let (store, plan) = if k == 0 {
        (dense.clone(), LayerPlan::all_dense(&pipe.cfg))
    } else {
        let calib = ctx.calibrate_cached(&pipe, &dense, 128)?;
        let (s, p, _) =
            ctx.compress_k(&pipe, &dense, &calib, k, LayerStrategy::Angular, &opts)?;
        (s, p)
    };
    let out = pipe.generate_greedy(&store, &plan, &[ids], n_new)?;
    println!("{} {}", prompt, ctx.vocab.decode(&out[0]));
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let mut ctx = Ctx::new()?;
    let config = args.str_opt("config", "tiny");
    let mode = args.str_opt("mode", "score");
    let clients = args.usize_opt("clients", 4);
    let per_client = args.usize_opt("requests", 8);
    let slots = args.usize_opt("slots", 4);
    let n_new = args.usize_opt("tokens", 24);
    let prompt_len = args.usize_opt("prompt-len", 8);
    let steps = args.usize_opt("steps", default_pretrain_steps());
    let kv_policy = KvPolicy::parse(&args.str_opt("kv-policy", "exact"))?;
    let deadline_ms = args.usize_opt("deadline-ms", 0);
    let queue_cap = args.usize_opt("queue-cap", 0);
    let workers = args.usize_opt("workers", 1);
    let retry_budget = args.usize_opt("retry-budget", 2);
    let heartbeat_ms = args.usize_opt("heartbeat-ms", 200);
    let faults = args.str_opt("faults", "");
    check_unknown(args)?;
    if !matches!(mode.as_str(), "score" | "generate" | "mixed") {
        bail!("unknown serve mode '{mode}' (score|generate|mixed)");
    }
    // Pretrain/load on the clean backend — faults apply to serving
    // traffic only, never to building the cached store.
    let dense = ctx.load_or_pretrain(&config, steps)?;
    let fault_plan = if faults.trim().is_empty() {
        None
    } else {
        let plan = curing::backend::fault::FaultPlan::parse(&faults)?;
        println!("injecting faults: {plan}");
        Some(plan)
    };
    let cfg = curing::model::ModelConfig::from_manifest(ctx.rt.manifest(), &config)?;
    let deadline = (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms as u64));
    let (tx, rx) = std::sync::mpsc::channel::<Request>();
    let (mut score_resps, mut gen_resps) = (Vec::new(), Vec::new());
    if mode == "score" || mode == "mixed" {
        score_resps = spawn_score_clients(
            &tx,
            &ctx.vocab,
            CorpusKind::SynthC4,
            cfg.seq,
            clients,
            per_client,
            5,
        );
    }
    if mode == "generate" || mode == "mixed" {
        gen_resps = spawn_gen_clients(
            &tx,
            &ctx.vocab,
            CorpusKind::SynthC4,
            prompt_len,
            n_new,
            clients,
            per_client,
            5,
        );
    }
    drop(tx);
    let stats = if workers > 1 {
        // Multi-worker path: each worker builds its own runtime
        // in-thread, so any fault plan rides the cluster's factory, not
        // `ctx.rt`.
        let mut cluster = ClusterServer::new(
            cfg.clone(),
            std::sync::Arc::new(dense),
            LayerPlan::all_dense(&cfg),
            workers,
        );
        cluster.slots = slots;
        cluster.kv_policy = kv_policy;
        cluster.max_wait = Duration::from_millis(30);
        cluster.deadline = deadline;
        cluster.queue_cap = queue_cap;
        cluster.retry_budget = retry_budget;
        cluster.heartbeat = Duration::from_millis(heartbeat_ms.max(1) as u64);
        let cluster = match fault_plan {
            Some(plan) => cluster.with_fault_plan(plan),
            None => cluster,
        };
        println!(
            "cluster: {workers} workers × {slots} slots | retry budget {retry_budget} | heartbeat {}ms",
            heartbeat_ms.max(1)
        );
        cluster.run(rx)?
    } else {
        if let Some(plan) = fault_plan {
            let rt = std::mem::replace(&mut ctx.rt, curing::runtime::Runtime::native());
            ctx.rt = rt.with_faults(plan);
        }
        let pipe = ctx.pipeline(&config)?;
        let server = GenerationServer {
            pipe: &pipe,
            store: &dense,
            plan: LayerPlan::all_dense(&pipe.cfg),
            max_wait: Duration::from_millis(30),
            slots,
            kv_policy,
            deadline,
            queue_cap,
            tick: None,
        };
        server.run(rx)?
    };
    if stats.served > 0 {
        println!(
            "scored {} reqs | {:.1} seq/s | occupancy {:.1}/{} | padded rows {} | p50 {:.0}ms p95 {:.0}ms",
            stats.served,
            stats.throughput_seq_per_s,
            stats.mean_batch_occupancy,
            cfg.batch,
            stats.padded_rows,
            stats.p50_latency_ms,
            stats.p95_latency_ms
        );
    }
    if stats.gen_served > 0 {
        println!(
            "generated {} reqs / {} toks | {:.1} tok/s | slots {:.1}/{} | prefills {} | tok p50 {:.2}ms p95 {:.2}ms",
            stats.gen_served,
            stats.tokens_generated,
            stats.tokens_per_s,
            stats.mean_active_slots,
            slots,
            stats.prefills,
            stats.tok_p50_ms,
            stats.tok_p95_ms
        );
        let exact_bound = workers.max(1)
            * slots
            * curing::backend::KvCache::exact_slot_bound(cfg.n_layers, cfg.seq, cfg.d_model);
        println!(
            "kv policy {kv_policy} | compactions {} | mean live KV {:.3} MiB (exact bound {:.3} MiB)",
            stats.kv_compactions,
            mib(stats.kv_live_bytes_mean),
            mib(exact_bound as f64)
        );
    }
    let troubled = stats.rejected
        + stats.timed_out
        + stats.slot_failures
        + stats.quarantined_slots
        + stats.degraded_steps;
    if troubled > 0 {
        println!(
            "robustness: rejected {} | timed out {} | slot failures {} | quarantined slots {} | degraded steps {}",
            stats.rejected,
            stats.timed_out,
            stats.slot_failures,
            stats.quarantined_slots,
            stats.degraded_steps
        );
    }
    if stats.worker_crashes + stats.worker_restarts + stats.retried_requests + stats.retired_workers
        > 0
    {
        println!(
            "cluster: worker crashes {} | restarts {} | retried requests {} | retired workers {}",
            stats.worker_crashes,
            stats.worker_restarts,
            stats.retried_requests,
            stats.retired_workers
        );
    }
    let (_, score_tally) = drain_score_responses(&score_resps);
    if score_tally.total() > 0 {
        println!("score outcomes: {score_tally}");
    }
    let (_, gen_tally) = drain_gen_responses(&gen_resps);
    if gen_tally.total() > 0 {
        println!("gen outcomes: {gen_tally}");
    }
    println!("wall {:.2}s", stats.wall_s);
    Ok(())
}

fn check_unknown(args: &Args) -> Result<()> {
    let unknown = args.unknown_flags();
    if !unknown.is_empty() {
        bail!("unknown flags: {unknown:?}");
    }
    Ok(())
}

//! Synthetic corpora standing in for C4 (calibration/healing) and
//! WikiText2 (distribution-shifted eval) — see DESIGN.md §2.
//!
//! Sentences come from topic-conditioned templates filled from the word
//! banks; the two corpora differ in topic mixture and template register,
//! which is exactly the property the experiments need: a model pretrained
//! on `synth-c4` sees `synth-wiki` as a shifted (higher-perplexity)
//! distribution, so healing-on-c4 vs forgetting-on-wiki dynamics mirror
//! the paper's C4/WikiText2 split.

use super::vocab::{Vocab, BOS, TOPICS};
use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorpusKind {
    /// Diverse informal mixture — the paper's C4 stand-in.
    SynthC4,
    /// Formal register, skewed topics — the WikiText2 stand-in.
    SynthWiki,
}

impl CorpusKind {
    pub fn label(&self) -> &'static str {
        match self {
            CorpusKind::SynthC4 => "synth-c4",
            CorpusKind::SynthWiki => "synth-wiki",
        }
    }

    /// Topic mixture weights (index-aligned with `vocab::TOPICS`).
    fn topic_weights(&self) -> [f32; 6] {
        match self {
            // c4: everything, slightly tilted to tech/cooking/sports chatter.
            CorpusKind::SynthC4 => [1.0, 1.4, 1.4, 1.5, 1.0, 0.7],
            // wiki: encyclopedic — history/science/nature heavy.
            CorpusKind::SynthWiki => [1.6, 0.4, 0.3, 0.7, 1.4, 1.9],
        }
    }
}

/// Sentence templates. `N`/`V`/`A` draw from the current topic bank;
/// lowercase literals are function words.
const CASUAL_TEMPLATES: &[&[&str]] = &[
    &["the", "N", "V", "the", "A", "N", "."],
    &["a", "A", "N", "V", "with", "a", "N", "."],
    &["this", "N", "is", "very", "A", "and", "it", "V", "often", "."],
    &["some", "N", "V", "before", "the", "N", "."],
    &["the", "A", "N", "never", "V", "but", "the", "N", "V", "."],
    &["many", "N", "V", "during", "the", "A", "N", "."],
    &["it", "is", "the", "N", "that", "V", "the", "N", "."],
];

const FORMAL_TEMPLATES: &[&[&str]] = &[
    &["the", "N", "of", "the", "A", "N", "V", "within", "the", "N", "."],
    &["moreover", ",", "the", "A", "N", "V", "against", "the", "N", "."],
    &["the", "N", ",", "which", "V", "during", "this", "era", ",", "is", "A", "."],
    &["therefore", "the", "N", "V", ";", "the", "N", "is", "A", "."],
    &["between", "the", "N", "and", "the", "N", ",", "the", "A", "N", "V", "."],
];

/// Deterministic streaming corpus generator.
pub struct Corpus {
    pub kind: CorpusKind,
    rng: Rng,
}

impl Corpus {
    /// `seed` selects the split: use distinct seeds for calibration,
    /// healing and eval so they never overlap (paper §5 requires this).
    pub fn new(kind: CorpusKind, seed: u64) -> Corpus {
        let stream = match kind {
            CorpusKind::SynthC4 => 0xc4,
            CorpusKind::SynthWiki => 0x111,
        };
        Corpus { kind, rng: Rng::new(seed, stream) }
    }

    /// One sentence as a word string.
    pub fn sentence(&mut self) -> String {
        let weights = self.kind.topic_weights();
        let t = self.rng.choice_weighted(&weights);
        let (_, nouns, verbs, adjs) = TOPICS[t];
        let templates = match self.kind {
            CorpusKind::SynthC4 => CASUAL_TEMPLATES,
            CorpusKind::SynthWiki => FORMAL_TEMPLATES,
        };
        let tpl = templates[self.rng.below(templates.len())];
        let mut out = Vec::with_capacity(tpl.len());
        for &slot in tpl {
            let w = match slot {
                "N" => nouns[self.rng.below(nouns.len())],
                "V" => verbs[self.rng.below(verbs.len())],
                "A" => adjs[self.rng.below(adjs.len())],
                lit => lit,
            };
            out.push(w);
        }
        out.join(" ")
    }

    /// A full token sequence of exactly `seq` tokens: `<bos>` + sentences.
    pub fn sequence(&mut self, vocab: &Vocab, seq: usize) -> Vec<i32> {
        let mut toks = vec![BOS];
        while toks.len() < seq {
            toks.extend(vocab.encode(&self.sentence()));
        }
        toks.truncate(seq);
        toks
    }

    /// A batch of `(tokens, targets)` pairs, each `seq` long; targets are
    /// tokens shifted left by one (next-token prediction).
    pub fn batch(&mut self, vocab: &Vocab, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let s = self.sequence(vocab, seq + 1);
            tokens.extend_from_slice(&s[..seq]);
            targets.extend_from_slice(&s[1..seq + 1]);
        }
        (tokens, targets)
    }

    /// Pretraining batch: a mixture of corpus text and task-format
    /// sequences (QA / multiple-choice / paraphrase templates) so the
    /// model learns the answer formats the evaluation suite probes —
    /// mirroring how web corpora expose real LLMs to QA text.
    pub fn batch_mixed(
        &mut self,
        vocab: &Vocab,
        batch: usize,
        seq: usize,
        task_fraction: f32,
    ) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let s = if self.rng.f32() < task_fraction {
                let mut s = super::tasks::task_sequence(vocab, &mut self.rng, seq + 1);
                debug_assert_eq!(s.len(), seq + 1);
                s.truncate(seq + 1);
                s
            } else {
                self.sequence(vocab, seq + 1)
            };
            tokens.extend_from_slice(&s[..seq]);
            targets.extend_from_slice(&s[1..seq + 1]);
        }
        (tokens, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab::UNK;

    #[test]
    fn sentences_fully_in_vocab() {
        let v = Vocab::build();
        for kind in [CorpusKind::SynthC4, CorpusKind::SynthWiki] {
            let mut c = Corpus::new(kind, 7);
            for _ in 0..50 {
                let s = c.sentence();
                let ids = v.encode(&s);
                assert!(!ids.contains(&UNK), "OOV in: {s}");
            }
        }
    }

    #[test]
    fn sequence_exact_length_and_bos() {
        let v = Vocab::build();
        let mut c = Corpus::new(CorpusKind::SynthC4, 1);
        let s = c.sequence(&v, 64);
        assert_eq!(s.len(), 64);
        assert_eq!(s[0], BOS);
    }

    #[test]
    fn batch_targets_are_shifted() {
        let v = Vocab::build();
        let mut c = Corpus::new(CorpusKind::SynthC4, 2);
        let (toks, tgts) = c.batch(&v, 4, 32);
        assert_eq!(toks.len(), 4 * 32);
        for b in 0..4 {
            for i in 0..31 {
                assert_eq!(toks[b * 32 + i + 1], tgts[b * 32 + i]);
            }
        }
    }

    #[test]
    fn corpora_are_distributionally_different() {
        // Unigram distributions of the two corpora must differ noticeably.
        let v = Vocab::build();
        let count = |kind| {
            let mut c = Corpus::new(kind, 3);
            let mut hist = vec![0f64; v.len()];
            for _ in 0..200 {
                for id in v.encode(&c.sentence()) {
                    hist[id as usize] += 1.0;
                }
            }
            let total: f64 = hist.iter().sum();
            hist.iter().map(|x| x / total).collect::<Vec<_>>()
        };
        let a = count(CorpusKind::SynthC4);
        let b = count(CorpusKind::SynthWiki);
        let l1: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(l1 > 0.3, "corpora too similar: L1={l1}");
    }

    #[test]
    fn seeds_give_disjoint_streams() {
        let v = Vocab::build();
        let mut a = Corpus::new(CorpusKind::SynthC4, 1);
        let mut b = Corpus::new(CorpusKind::SynthC4, 2);
        let sa: Vec<String> = (0..10).map(|_| a.sentence()).collect();
        let sb: Vec<String> = (0..10).map(|_| b.sentence()).collect();
        assert_ne!(sa, sb);
        let _ = v;
    }
}

//! Synthetic evaluation/fine-tuning tasks mirroring the paper's suite:
//!
//! * `boolq`  — yes/no questions about generated facts (BoolQ stand-in,
//!   random baseline 0.5);
//! * `mmlu`   — 4-choice questions answered by a letter (MMLU stand-in,
//!   random baseline 0.25);
//! * `mrpc`   — paraphrase detection pairs for the Fig. 6 forgetting
//!   experiment;
//! * `uuid`   — the paper's exact UUID→UUID memorization task (Fig. 7,
//!   App. B prompt format), char-level.
//!
//! Every task instance is a token sequence plus the index of the answer
//! position(s), so choice scoring = comparing forced-answer NLL.

use super::vocab::{Vocab, BOS, TOPICS};
use crate::util::Rng;

/// A scored-choice task instance: context is teacher-forced; each choice
/// is a candidate continuation starting at `answer_pos`.
#[derive(Debug, Clone)]
pub struct ChoiceItem {
    /// Full token sequence including the *gold* answer filled in.
    pub tokens: Vec<i32>,
    /// Position of the answer token (targets index).
    pub answer_pos: usize,
    /// Candidate answer token ids; `gold` indexes into this.
    pub choices: Vec<i32>,
    pub gold: usize,
}

/// A fine-tuning instance: sequence + per-position loss mask over the
/// answer span.
#[derive(Debug, Clone)]
pub struct TrainItem {
    pub tokens: Vec<i32>,
    /// Mask aligned with *targets* (tokens shifted by one).
    pub loss_mask: Vec<f32>,
}

/// BoolQ-like: state a fact, ask about it; half the questions negate the
/// attribute. "the atom is stable . question : is the atom stable ?
/// answer : yes"
pub fn boolq_item(vocab: &Vocab, rng: &mut Rng, seq: usize) -> ChoiceItem {
    let (_, nouns, _, adjs) = TOPICS[rng.below(TOPICS.len())];
    let noun = nouns[rng.below(nouns.len())];
    let adj_true = adjs[rng.below(adjs.len())];
    let mut adj_asked = adj_true;
    let is_yes = rng.below(2) == 0;
    if !is_yes {
        // Ask about a different attribute.
        loop {
            let a = adjs[rng.below(adjs.len())];
            if a != adj_true {
                adj_asked = a;
                break;
            }
        }
    }
    let answer = if is_yes { "yes" } else { "no" };
    let text = format!(
        "the {noun} is {adj_true} . question : is the {noun} {adj_asked} ? answer : {answer}"
    );
    let mut tokens = vec![BOS];
    tokens.extend(vocab.encode(&text));
    let answer_pos = tokens.len() - 2; // target index of the answer token
    pad_or_trim(&mut tokens, seq);
    let choices = vec![vocab.id("yes"), vocab.id("no")];
    ChoiceItem { tokens, answer_pos, choices, gold: if is_yes { 0 } else { 1 } }
}

/// MMLU-like 4-choice: "question : which N V ? ( a ) N ( b ) N ( c ) N
/// ( d ) N answer : b".
pub fn mmlu_item(vocab: &Vocab, rng: &mut Rng, seq: usize) -> ChoiceItem {
    let (_, nouns, verbs, adjs) = TOPICS[rng.below(TOPICS.len())];
    let verb = verbs[rng.below(verbs.len())];
    let adj = adjs[rng.below(adjs.len())];
    // Four distinct option nouns; the "correct" one is the one stated in
    // the context sentence.
    let opts = rng.sample_distinct(nouns.len(), 4.min(nouns.len()));
    let gold = rng.below(4);
    let letters = ["a", "b", "c", "d"];
    let mut text = format!("the {} {} and is {} . question : which {} ", nouns[opts[gold]], verb, adj, verb);
    text.push('?');
    for (i, &o) in opts.iter().enumerate() {
        text.push_str(&format!(" ( {} ) {}", letters[i], nouns[o]));
    }
    text.push_str(&format!(" answer : {}", letters[gold]));
    let mut tokens = vec![BOS];
    tokens.extend(vocab.encode(&text));
    let answer_pos = tokens.len() - 2;
    pad_or_trim(&mut tokens, seq);
    let choices = letters.iter().map(|l| vocab.id(l)).collect();
    ChoiceItem { tokens, answer_pos, choices, gold }
}

/// MRPC-like paraphrase pair for fine-tuning + accuracy eval. Positive
/// pairs restate the same (noun, adj) with a different template; negative
/// pairs change the attribute or subject.
pub fn mrpc_item(vocab: &Vocab, rng: &mut Rng, seq: usize) -> (ChoiceItem, TrainItem) {
    let (_, nouns, _, adjs) = TOPICS[rng.below(TOPICS.len())];
    let noun = nouns[rng.below(nouns.len())];
    let adj = adjs[rng.below(adjs.len())];
    let positive = rng.below(2) == 0;
    let (noun2, adj2) = if positive {
        (noun, adj)
    } else if rng.below(2) == 0 {
        (nouns[rng.below(nouns.len())], adj)
    } else {
        (noun, adjs[rng.below(adjs.len())])
    };
    // A "negative" that accidentally sampled identical words is positive.
    let actually_pos = noun2 == noun && adj2 == adj;
    let answer = if actually_pos { "yes" } else { "no" };
    let text = format!(
        "first : the {noun} is {adj} . second : this {noun2} is very {adj2} . paraphrase : {answer}"
    );
    let mut tokens = vec![BOS];
    tokens.extend(vocab.encode(&text));
    let answer_pos = tokens.len() - 2;
    pad_or_trim(&mut tokens, seq);
    let choices = vec![vocab.id("yes"), vocab.id("no")];
    let item = ChoiceItem {
        tokens: tokens.clone(),
        answer_pos,
        choices,
        gold: if actually_pos { 0 } else { 1 },
    };
    let mut mask = vec![0.0f32; seq];
    if answer_pos < seq {
        mask[answer_pos] = 1.0;
    }
    (item, TrainItem { tokens, loss_mask: mask })
}

/// One random UUID string (hex 8-4-4-4-12) from our RNG.
pub fn uuid_string(rng: &mut Rng) -> String {
    const HEXC: &[u8] = b"0123456789abcdef";
    let mut s = String::with_capacity(36);
    for (i, group) in [8usize, 4, 4, 4, 12].iter().enumerate() {
        if i > 0 {
            s.push('-');
        }
        for _ in 0..*group {
            s.push(HEXC[rng.below(16)] as char);
        }
    }
    s
}

/// The paper's UUID→UUID pair task (App. B):
/// `given this uuid : <in> the corresponding uuid is : <out>`,
/// char-level for the UUIDs. Loss mask covers the output UUID chars.
pub fn uuid_item(vocab: &Vocab, input: &str, output: &str, seq: usize) -> TrainItem {
    let mut tokens = vec![BOS];
    tokens.extend(vocab.encode("given this uuid :"));
    tokens.extend(vocab.encode_chars(input));
    tokens.extend(vocab.encode("the corresponding uuid is :"));
    let out_start = tokens.len();
    tokens.extend(vocab.encode_chars(output));
    let out_end = tokens.len();
    let mut mask = vec![0.0f32; seq];
    // Mask on targets: predicting token at position i+1 from position i.
    for i in out_start..out_end {
        if i >= 1 && i - 1 < seq {
            mask[i - 1] = 1.0;
        }
    }
    pad_or_trim(&mut tokens, seq);
    TrainItem { tokens, loss_mask: mask }
}

/// The fixed 1,024-pair UUID mapping (paper uses 1,024 pairs).
pub fn uuid_pairs(n: usize, seed: u64) -> Vec<(String, String)> {
    let mut rng = Rng::new(seed, 0x7575_6964); // "uuid" stream tag
    (0..n).map(|_| (uuid_string(&mut rng), uuid_string(&mut rng))).collect()
}

/// A seq-length token stream of concatenated task-format items (boolq /
/// mmlu / mrpc), used to mix instruction formats into *pretraining* so
/// the forced-choice evaluations are meaningful (the paper's base models
/// saw QA formats in their corpora; our synthetic C4 must too).
pub fn task_sequence(vocab: &Vocab, rng: &mut Rng, seq: usize) -> Vec<i32> {
    let mut toks = vec![super::vocab::BOS];
    while toks.len() < seq {
        let kind = rng.below(3);
        let item_toks = match kind {
            0 => boolq_item(vocab, rng, seq).tokens,
            1 => mmlu_item(vocab, rng, seq).tokens,
            _ => mrpc_item(vocab, rng, seq).0.tokens,
        };
        // Strip bos + padding before splicing.
        let end = item_toks
            .iter()
            .rposition(|&t| t != super::vocab::PAD)
            .map(|i| i + 1)
            .unwrap_or(item_toks.len());
        toks.extend_from_slice(&item_toks[1..end]);
    }
    toks.truncate(seq);
    toks
}

fn pad_or_trim(tokens: &mut Vec<i32>, seq: usize) {
    tokens.truncate(seq);
    while tokens.len() < seq {
        tokens.push(super::vocab::PAD);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab::UNK;

    #[test]
    fn boolq_wellformed() {
        let v = Vocab::build();
        let mut rng = Rng::new(1, 0);
        for _ in 0..50 {
            let it = boolq_item(&v, &mut rng, 64);
            assert_eq!(it.tokens.len(), 64);
            assert!(!it.tokens.contains(&UNK));
            assert_eq!(it.choices.len(), 2);
            // Gold answer token actually sits at answer_pos + 1.
            assert_eq!(it.tokens[it.answer_pos + 1], it.choices[it.gold]);
        }
    }

    #[test]
    fn mmlu_wellformed() {
        let v = Vocab::build();
        let mut rng = Rng::new(2, 0);
        for _ in 0..50 {
            let it = mmlu_item(&v, &mut rng, 64);
            assert_eq!(it.choices.len(), 4);
            assert!(it.gold < 4);
            assert_eq!(it.tokens[it.answer_pos + 1], it.choices[it.gold]);
            assert!(!it.tokens.contains(&UNK));
        }
    }

    #[test]
    fn mrpc_label_consistency() {
        let v = Vocab::build();
        let mut rng = Rng::new(3, 0);
        let (mut yes, mut no) = (0, 0);
        for _ in 0..100 {
            let (item, train) = mrpc_item(&v, &mut rng, 64);
            assert_eq!(item.tokens, train.tokens);
            assert_eq!(train.loss_mask.iter().filter(|&&m| m > 0.0).count(), 1);
            if item.gold == 0 {
                yes += 1;
            } else {
                no += 1;
            }
        }
        assert!(yes > 20 && no > 20, "labels unbalanced: {yes}/{no}");
    }

    #[test]
    fn uuid_format_and_mask() {
        let v = Vocab::build();
        let mut rng = Rng::new(4, 0);
        let u = uuid_string(&mut rng);
        assert_eq!(u.len(), 36);
        assert_eq!(u.matches('-').count(), 4);
        let pairs = uuid_pairs(8, 42);
        assert_eq!(pairs.len(), 8);
        let item = uuid_item(&v, &pairs[0].0, &pairs[0].1, 128);
        assert_eq!(item.tokens.len(), 128);
        assert!(!item.tokens.contains(&UNK));
        // 36 masked target positions (the output uuid chars).
        assert_eq!(item.loss_mask.iter().filter(|&&m| m > 0.0).count(), 36);
    }

    #[test]
    fn uuid_pairs_deterministic() {
        assert_eq!(uuid_pairs(4, 9), uuid_pairs(4, 9));
        assert_ne!(uuid_pairs(4, 9), uuid_pairs(4, 10));
    }
}

//! Vocabulary + word-level tokenizer for the synthetic corpora.
//!
//! The vocabulary is fixed and deterministic (it must fit the AOT model's
//! embedding table exactly): special tokens, punctuation, answer/choice
//! words, hex characters for the UUID task, and a topical word bank used
//! by the corpus generators.

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const UNK: i32 = 3;

/// Topic word banks: (topic name, nouns, verbs, adjectives).
pub const TOPICS: &[(&str, &[&str], &[&str], &[&str])] = &[
    (
        "science",
        &["atom", "cell", "energy", "photon", "theory", "experiment", "molecule", "gene",
          "neuron", "galaxy", "enzyme", "electron", "fossil", "orbit", "quantum", "vaccine"],
        &["reacts", "evolves", "decays", "absorbs", "emits", "mutates", "accelerates", "binds"],
        &["stable", "radioactive", "organic", "microscopic", "massive", "charged", "ancient"],
    ),
    (
        "sports",
        &["team", "player", "match", "goal", "season", "coach", "league", "stadium",
          "record", "tournament", "defense", "striker", "referee", "trophy"],
        &["wins", "scores", "defends", "trains", "competes", "loses", "celebrates", "passes"],
        &["fast", "strong", "undefeated", "young", "veteran", "injured", "brilliant"],
    ),
    (
        "cooking",
        &["recipe", "sauce", "oven", "flavor", "bread", "butter", "garlic", "spice",
          "kitchen", "dough", "dish", "onion", "pepper", "flour"],
        &["simmers", "bakes", "melts", "rises", "burns", "blends", "tastes", "cools"],
        &["fresh", "spicy", "sweet", "crispy", "tender", "bitter", "golden"],
    ),
    (
        "tech",
        &["server", "network", "compiler", "kernel", "algorithm", "database", "protocol",
          "cache", "processor", "software", "cluster", "packet", "thread", "memory"],
        &["computes", "crashes", "scales", "compiles", "encrypts", "routes", "executes", "syncs"],
        &["distributed", "parallel", "secure", "efficient", "legacy", "virtual", "fault-tolerant"],
    ),
    (
        "nature",
        &["forest", "river", "mountain", "storm", "ocean", "valley", "glacier", "desert",
          "meadow", "island", "canyon", "volcano", "reef", "tundra"],
        &["flows", "erodes", "erupts", "freezes", "blooms", "migrates", "drifts", "grows"],
        &["vast", "remote", "frozen", "tropical", "arid", "lush", "deep"],
    ),
    (
        "history",
        &["empire", "treaty", "dynasty", "revolution", "kingdom", "archive", "monument",
          "senate", "frontier", "colony", "manuscript", "fortress", "republic", "era"],
        &["collapses", "expands", "declares", "conquers", "reforms", "endures", "signs", "falls"],
        &["medieval", "ancient", "colonial", "imperial", "feudal", "modern", "forgotten"],
    ),
];

pub const FUNCTION_WORDS: &[&str] = &[
    "the", "a", "an", "of", "in", "on", "and", "or", "but", "with", "by", "for", "to",
    "is", "are", "was", "were", "has", "have", "had", "will", "can", "must", "may",
    "this", "that", "these", "those", "it", "its", "as", "at", "from", "into", "over",
    "under", "between", "after", "before", "while", "when", "where", "which", "who",
    "not", "no", "very", "more", "most", "some", "many", "few", "each", "every", "both",
    "often", "rarely", "always", "never", "usually", "then", "thus", "therefore",
    "however", "moreover", "because", "although", "during", "within", "against",
];

pub const PUNCT: &[&str] = &[".", ",", "?", ":", ";", "(", ")", "-"];

pub const ANSWER_WORDS: &[&str] =
    &["yes", "no", "true", "false", "question", "answer", "paraphrase", "sentence",
      "choice", "correct", "given", "corresponding", "uuid", "same", "different",
      "means", "compare", "first", "second", "passage", "color", "size", "number"];

pub const HEX: &[&str] = &["0", "1", "2", "3", "4", "5", "6", "7", "8", "9",
                           "a", "b", "c", "d", "e", "f"];

/// The fixed tokenizer. Token ids are stable across runs (vocabulary is
/// built in deterministic order) and must stay below the model's vocab.
#[derive(Debug, Clone)]
pub struct Vocab {
    words: Vec<String>,
    index: HashMap<String, i32>,
}

impl Vocab {
    pub fn build() -> Vocab {
        let mut words: Vec<String> =
            vec!["<pad>".into(), "<bos>".into(), "<eos>".into(), "<unk>".into()];
        let push = |w: &str, words: &mut Vec<String>| {
            if !words.iter().any(|x| x == w) {
                words.push(w.to_string());
            }
        };
        for p in PUNCT {
            push(p, &mut words);
        }
        for w in ANSWER_WORDS {
            push(w, &mut words);
        }
        for h in HEX {
            push(h, &mut words);
        }
        for w in FUNCTION_WORDS {
            push(w, &mut words);
        }
        for (_, nouns, verbs, adjs) in TOPICS {
            for w in nouns.iter().chain(verbs.iter()).chain(adjs.iter()) {
                push(w, &mut words);
            }
        }
        let index =
            words.iter().enumerate().map(|(i, w)| (w.clone(), i as i32)).collect();
        Vocab { words, index }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn id(&self, word: &str) -> i32 {
        *self.index.get(word).unwrap_or(&UNK)
    }

    pub fn word(&self, id: i32) -> &str {
        self.words.get(id as usize).map(|s| s.as_str()).unwrap_or("<unk>")
    }

    /// Tokenize whitespace-separated text (words must be pre-normalized;
    /// the generators only emit in-vocabulary words).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter().map(|&i| self.word(i)).collect::<Vec<_>>().join(" ")
    }

    /// Encode a UUID string character-by-character (hex digits + '-').
    pub fn encode_chars(&self, s: &str) -> Vec<i32> {
        s.chars().map(|c| self.id(&c.to_string())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_fits_model_embedding() {
        let v = Vocab::build();
        assert!(v.len() <= 512, "vocab {} exceeds tiny model embedding", v.len());
        assert!(v.len() >= 250, "vocab suspiciously small: {}", v.len());
    }

    #[test]
    fn specials_are_fixed() {
        let v = Vocab::build();
        assert_eq!(v.id("<pad>"), PAD);
        assert_eq!(v.id("<bos>"), BOS);
        assert_eq!(v.id("<eos>"), EOS);
        assert_eq!(v.id("<unk>"), UNK);
    }

    #[test]
    fn roundtrip() {
        let v = Vocab::build();
        let text = "the atom reacts with the molecule .";
        let ids = v.encode(text);
        assert!(ids.iter().all(|&i| i != UNK));
        assert_eq!(v.decode(&ids), text);
    }

    #[test]
    fn unknown_maps_to_unk() {
        let v = Vocab::build();
        assert_eq!(v.encode("zzzunknownzzz"), vec![UNK]);
    }

    #[test]
    fn uuid_chars_in_vocab() {
        let v = Vocab::build();
        let ids = v.encode_chars("3f2a-9b");
        assert!(ids.iter().all(|&i| i != UNK));
        assert_eq!(ids.len(), 7);
    }

    #[test]
    fn deterministic_ids() {
        let a = Vocab::build();
        let b = Vocab::build();
        for w in ["atom", "yes", "the", "f", "."] {
            assert_eq!(a.id(w), b.id(w));
        }
    }
}

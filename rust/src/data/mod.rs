//! Data substrate: vocabulary/tokenizer, synthetic corpora (C4/WikiText2
//! stand-ins) and the evaluation/fine-tuning tasks.

pub mod corpus;
pub mod tasks;
pub mod vocab;

pub use corpus::{Corpus, CorpusKind};
pub use tasks::{boolq_item, mmlu_item, mrpc_item, uuid_item, uuid_pairs, ChoiceItem, TrainItem};
pub use vocab::Vocab;

/// Canonical split seeds (paper: calibration, healing and eval data must
/// not overlap).
pub const SEED_CALIB: u64 = 1001;
pub const SEED_HEAL: u64 = 2002;
pub const SEED_EVAL: u64 = 3003;
pub const SEED_PRETRAIN: u64 = 4004;

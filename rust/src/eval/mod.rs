//! Evaluation: perplexity, forced-choice accuracy, char-level accuracy,
//! and the Table 6 activation-norm analysis.
//!
//! Two forward paths:
//! * the per-layer [`Pipeline`] (dense or ΔU-cured models);
//! * the switched full-model logits for PEFT-adapted models, via
//!   [`crate::backend::Backend::switched_logits`] (native blended
//!   forward, or the `model_logits_switched_{du,lora,mora,curlora}`
//!   artifacts on pjrt).

use crate::backend::{Backend, KvCache, KvPolicy};
use crate::data::ChoiceItem;
use crate::data::{Corpus, Vocab};
use crate::linalg::Mat;
use crate::peft::Adapter;
use crate::pipeline::{LayerPlan, Pipeline};
use crate::tensor::{Tensor, TensorStore};
use anyhow::{ensure, Result};

/// Mean per-token NLL over `n_batches` from `corpus`; ppl = exp(nll).
pub fn perplexity(
    pipe: &Pipeline,
    store: &TensorStore,
    plan: &LayerPlan,
    vocab: &Vocab,
    corpus: &mut Corpus,
    n_batches: usize,
) -> Result<f64> {
    let cfg = &pipe.cfg;
    let mut total = 0.0f64;
    let mut count = 0usize;
    for _ in 0..n_batches {
        let (toks, tgts) = corpus.batch(vocab, cfg.batch, cfg.seq);
        let tokens = Tensor::from_i32(&[cfg.batch, cfg.seq], toks);
        let targets = Tensor::from_i32(&[cfg.batch, cfg.seq], tgts);
        let nll = pipe.nll(store, plan, &tokens, &targets)?;
        for &x in nll.f32s()? {
            total += x as f64;
            count += 1;
        }
    }
    Ok((total / count as f64).exp())
}

/// Teacher-forced perplexity through the *decode* path under a KV
/// eviction policy: each sequence runs one token per step through a
/// single-slot cache — compacting whenever the lane fills, exactly like
/// serving traffic under `--kv-policy` — and the next-token NLL is read
/// off the decode-step logits. The quality harness for the compressed
/// KV cache: run it twice on sequences longer than the attention window
/// (so compaction actually fires), once with [`KvPolicy::Exact`] and
/// once with [`KvPolicy::Cur`], and the ratio is the perplexity cost of
/// the evicted positions. `ppl = exp(mean per-token NLL)`.
pub fn decode_perplexity(
    pipe: &Pipeline,
    store: &TensorStore,
    plan: &LayerPlan,
    policy: KvPolicy,
    seqs: &[Vec<i32>],
) -> Result<f64> {
    let cfg = &pipe.cfg;
    ensure!(!seqs.is_empty(), "decode perplexity needs at least one sequence");
    policy.validate(cfg.seq)?;
    let packed = pipe.pack_head(store)?;
    let mut total = 0.0f64;
    let mut count = 0usize;
    for seq in seqs {
        ensure!(seq.len() >= 2, "decode perplexity needs sequences of >= 2 tokens");
        let mut kv = KvCache::with_policy(cfg.n_layers, 1, cfg.seq, cfg.d_model, policy);
        for i in 0..seq.len() - 1 {
            let logits = pipe.decode_step_logits(
                store,
                plan,
                &mut kv,
                &[0],
                &[seq[i]],
                packed.as_ref(),
            )?;
            let row = &logits.f32s()?[..cfg.vocab];
            let t = seq[i + 1];
            ensure!(
                (0..cfg.vocab as i32).contains(&t),
                "target token {t} out of vocab 0..{}",
                cfg.vocab
            );
            total += nll_row(row, t as usize);
            count += 1;
        }
    }
    Ok((total / count as f64).exp())
}

/// Pack choice items into model batches; returns padded token tensors and
/// the originating item index of each row.
fn pack_items(items: &[ChoiceItem], batch: usize, seq: usize) -> Vec<(Tensor, Vec<usize>)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < items.len() {
        let mut toks = Vec::with_capacity(batch * seq);
        let mut idx = Vec::with_capacity(batch);
        for b in 0..batch {
            let j = (i + b).min(items.len() - 1); // pad with last item
            toks.extend_from_slice(&items[j].tokens);
            idx.push(j);
        }
        out.push((Tensor::from_i32(&[batch, seq], toks), idx));
        i += batch;
    }
    out
}

/// Score one packed batch of logits against the items' choices.
fn score_batch(
    logits: &Tensor,
    items: &[ChoiceItem],
    idx: &[usize],
    seen: &mut vec::BitSet,
    correct: &mut usize,
    total: &mut usize,
) -> Result<()> {
    let (b, s, v) = (logits.shape[0], logits.shape[1], logits.shape[2]);
    let data = logits.f32s()?;
    for (row, &item_i) in idx.iter().enumerate().take(b) {
        if seen.contains(item_i) {
            continue;
        }
        seen.insert(item_i);
        let item = &items[item_i];
        ensure!(item.answer_pos < s, "answer position beyond sequence");
        let base = (row * s + item.answer_pos) * v;
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (ci, &tok) in item.choices.iter().enumerate() {
            let val = data[base + tok as usize];
            if val > best_v {
                best_v = val;
                best = ci;
            }
        }
        if best == item.gold {
            *correct += 1;
        }
        *total += 1;
    }
    Ok(())
}

mod vec {
    /// Tiny bitset (items seen) — avoids double counting padded rows.
    pub struct BitSet(Vec<bool>);

    impl BitSet {
        pub fn new(n: usize) -> BitSet {
            BitSet(vec![false; n])
        }

        pub fn contains(&self, i: usize) -> bool {
            self.0[i]
        }

        pub fn insert(&mut self, i: usize) {
            self.0[i] = true;
        }
    }
}

/// Forced-choice accuracy via the per-layer pipeline.
pub fn choice_accuracy(
    pipe: &Pipeline,
    store: &TensorStore,
    plan: &LayerPlan,
    items: &[ChoiceItem],
) -> Result<f64> {
    let cfg = &pipe.cfg;
    let mut seen = vec::BitSet::new(items.len());
    let (mut correct, mut total) = (0usize, 0usize);
    for (tokens, idx) in pack_items(items, cfg.batch, cfg.seq) {
        let logits = pipe.logits(store, plan, &tokens)?;
        score_batch(&logits, items, &idx, &mut seen, &mut correct, &mut total)?;
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Logits of an adapter-blended (switched) model, routed through the
/// backend: the native blended forward, or the switched logits artifact
/// on pjrt. Missing tensors of the active adapter family — or of a
/// cured layer's factors — are hard errors on every backend: a typo'd
/// tensor name must never silently evaluate the base model.
pub fn switched_logits(
    pipe: &Pipeline,
    teacher: &TensorStore,
    student: &TensorStore,
    adapters: &TensorStore,
    adapter: Adapter,
    tokens: &Tensor,
) -> Result<Tensor> {
    pipe.rt.backend().switched_logits(&pipe.cfg, teacher, student, adapters, adapter, tokens)
}

/// Per-row NLL from a logits row: max-subtracted logsumexp minus the
/// target logit, accumulated in f64. The single definition every
/// host-side NLL path shares — the decode-path quality harness depends
/// on exact vs compressed runs computing this identically.
fn nll_row(row: &[f32], target: usize) -> f64 {
    let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let logz = maxv + row.iter().map(|&x| ((x as f64) - maxv).exp()).sum::<f64>().ln();
    logz - row[target] as f64
}

/// Host-side mean NLL from logits + targets (used for adapted models).
pub fn nll_from_logits_host(logits: &Tensor, targets: &[i32], mask: Option<&[f32]>) -> Result<f64> {
    let (b, s, v) = (logits.shape[0], logits.shape[1], logits.shape[2]);
    ensure!(targets.len() == b * s, "targets length mismatch");
    let data = logits.f32s()?;
    let mut total = 0.0f64;
    let mut wsum = 0.0f64;
    for i in 0..b * s {
        let w = mask.map(|m| m[i] as f64).unwrap_or(1.0);
        if w == 0.0 {
            continue;
        }
        let row = &data[i * v..(i + 1) * v];
        total += w * nll_row(row, targets[i] as usize);
        wsum += w;
    }
    Ok(total / wsum.max(1.0))
}

/// Char-level accuracy on masked positions (UUID task, Fig. 7): argmax
/// prediction vs target where mask > 0, teacher-forced.
pub fn char_accuracy_host(logits: &Tensor, targets: &[i32], mask: &[f32]) -> Result<f64> {
    let (b, s, v) = (logits.shape[0], logits.shape[1], logits.shape[2]);
    ensure!(targets.len() == b * s && mask.len() == b * s);
    let data = logits.f32s()?;
    let (mut correct, mut total) = (0usize, 0usize);
    for i in 0..b * s {
        if mask[i] == 0.0 {
            continue;
        }
        let row = &data[i * v..(i + 1) * v];
        let mut am = 0usize;
        let mut best = f32::NEG_INFINITY;
        for (j, &x) in row.iter().enumerate() {
            if x > best {
                best = x;
                am = j;
            }
        }
        if am as i32 == targets[i] {
            correct += 1;
        }
        total += 1;
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Perplexity of an adapted (switched) model over a corpus.
pub fn perplexity_switched(
    pipe: &Pipeline,
    teacher: &TensorStore,
    student: &TensorStore,
    adapters: &TensorStore,
    adapter: Adapter,
    vocab: &Vocab,
    corpus: &mut Corpus,
    n_batches: usize,
) -> Result<f64> {
    let cfg = &pipe.cfg;
    let mut acc = 0.0;
    for _ in 0..n_batches {
        let (toks, tgts) = corpus.batch(vocab, cfg.batch, cfg.seq);
        let tokens = Tensor::from_i32(&[cfg.batch, cfg.seq], toks);
        let logits = switched_logits(pipe, teacher, student, adapters, adapter, &tokens)?;
        acc += nll_from_logits_host(&logits, &tgts, None)?;
    }
    Ok((acc / n_batches as f64).exp())
}

/// Forced-choice accuracy via a switched (adapter-aware) model.
pub fn choice_accuracy_switched(
    pipe: &Pipeline,
    teacher: &TensorStore,
    student: &TensorStore,
    adapters: &TensorStore,
    adapter: Adapter,
    items: &[ChoiceItem],
) -> Result<f64> {
    let cfg = &pipe.cfg;
    let mut seen = vec::BitSet::new(items.len());
    let (mut correct, mut total) = (0usize, 0usize);
    for (tokens, idx) in pack_items(items, cfg.batch, cfg.seq) {
        let logits = switched_logits(pipe, teacher, student, adapters, adapter, &tokens)?;
        score_batch(&logits, items, &idx, &mut seen, &mut correct, &mut total)?;
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Pack fine-tuning items into one model batch: (tokens, targets, mask).
/// Targets are the tokens shifted left by one; the mask is the items'
/// answer-span mask (aligned with targets). Items are cycled if fewer
/// than the batch size.
pub fn pack_train(
    items: &[crate::data::TrainItem],
    start: usize,
    batch: usize,
    seq: usize,
) -> (Tensor, Tensor, Tensor) {
    let mut toks = Vec::with_capacity(batch * seq);
    let mut tgts = Vec::with_capacity(batch * seq);
    let mut mask = Vec::with_capacity(batch * seq);
    for b in 0..batch {
        let it = &items[(start + b) % items.len()];
        toks.extend_from_slice(&it.tokens);
        // Next-token targets within the fixed window.
        tgts.extend_from_slice(&it.tokens[1..]);
        tgts.push(crate::data::vocab::PAD);
        mask.extend_from_slice(&it.loss_mask);
    }
    (
        Tensor::from_i32(&[batch, seq], toks),
        Tensor::from_i32(&[batch, seq], tgts),
        Tensor::from_f32(&[batch, seq], mask),
    )
}

/// Table 6 row: activation Frobenius norms of one projection.
#[derive(Debug, Clone)]
pub struct ActivationRow {
    pub layer: usize,
    pub proj: String,
    /// ‖X W‖_F under the teacher (dense) weights.
    pub teacher_norm: f64,
    /// ‖((X C) U) R‖_F under the student factors (U = U0 + dU).
    pub student_norm: f64,
    /// ‖W − C U R‖_F.
    pub weight_diff: f64,
}

/// Compute Table 6 activation norms for the cured projections of `layer`.
/// `x_attn`/`x_ffn` are the raw projection inputs from a calibration
/// forward (`CalibForward::attn_in` / `ffn_in`).
pub fn activation_rows(
    teacher: &TensorStore,
    student: &TensorStore,
    layer: usize,
    x_attn: &Tensor,
    x_ffn: &Tensor,
) -> Result<Vec<ActivationRow>> {
    let mut rows = Vec::new();
    for proj in ["q", "k", "gate"] {
        let wname = format!("L{layer}.w_{proj}");
        let w = Mat::from_tensor(teacher.get(&wname)?)?;
        let x3 = if proj == "gate" { x_ffn } else { x_attn };
        let x = flatten_to_mat(x3)?;
        let teacher_norm = x.matmul(&w).fro_norm();
        let (student_norm, weight_diff) = if student.contains(&format!("L{layer}.c_{proj}")) {
            let c = Mat::from_tensor(student.get(&format!("L{layer}.c_{proj}"))?)?;
            let u0 = Mat::from_tensor(student.get(&format!("L{layer}.u_{proj}"))?)?;
            let du = Mat::from_tensor(student.get(&format!("L{layer}.du_{proj}"))?)?;
            let r = Mat::from_tensor(student.get(&format!("L{layer}.r_{proj}"))?)?;
            let mut u = u0.clone();
            for (a, b) in u.data.iter_mut().zip(&du.data) {
                *a += b;
            }
            let sn = x.matmul(&c).matmul(&u).matmul(&r).fro_norm();
            let wd = w.sub(&c.matmul(&u).matmul(&r)).fro_norm();
            (sn, wd)
        } else {
            // Uncompressed weight: student == teacher (paper Table 6 shows
            // zero diff for untouched layers).
            (teacher_norm, 0.0)
        };
        rows.push(ActivationRow {
            layer,
            proj: proj.to_string(),
            teacher_norm,
            student_norm,
            weight_diff,
        });
    }
    Ok(rows)
}

fn flatten_to_mat(t: &Tensor) -> Result<Mat> {
    ensure!(t.shape.len() == 3, "expected (b, s, d)");
    let flat = Tensor::from_f32(&[t.shape[0] * t.shape[1], t.shape[2]], t.f32s()?.to_vec());
    Mat::from_tensor(&flat)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nll_host_matches_manual() {
        // 1x1x3 logits; uniform => nll = ln 3.
        let logits = Tensor::from_f32(&[1, 1, 3], vec![0.0, 0.0, 0.0]);
        let nll = nll_from_logits_host(&logits, &[1], None).unwrap();
        assert!((nll - 3.0f64.ln()).abs() < 1e-6);
        // Peaked logits on the target => near-zero nll.
        let logits = Tensor::from_f32(&[1, 1, 3], vec![0.0, 20.0, 0.0]);
        let nll = nll_from_logits_host(&logits, &[1], None).unwrap();
        assert!(nll < 1e-6);
    }

    #[test]
    fn nll_host_mask_selects_positions() {
        let logits = Tensor::from_f32(&[1, 2, 2], vec![10.0, 0.0, 0.0, 10.0]);
        // Position 0 predicts 0 (nll~0), position 1 predicts 1 (nll~0 for
        // target 1; large for target 0).
        let full = nll_from_logits_host(&logits, &[0, 0], None).unwrap();
        let masked = nll_from_logits_host(&logits, &[0, 0], Some(&[1.0, 0.0])).unwrap();
        assert!(masked < full);
    }

    #[test]
    fn char_accuracy_counts_masked_only() {
        let logits = Tensor::from_f32(&[1, 2, 2], vec![5.0, 0.0, 0.0, 5.0]);
        // Predictions: [0, 1]. Targets [0, 0]: pos0 right, pos1 wrong.
        let acc = char_accuracy_host(&logits, &[0, 0], &[1.0, 1.0]).unwrap();
        assert!((acc - 0.5).abs() < 1e-9);
        let acc = char_accuracy_host(&logits, &[0, 0], &[1.0, 0.0]).unwrap();
        assert!((acc - 1.0).abs() < 1e-9);
    }

    #[test]
    fn pack_items_covers_all_and_pads() {
        let items: Vec<ChoiceItem> = (0..5)
            .map(|i| ChoiceItem {
                tokens: vec![i as i32; 8],
                answer_pos: 3,
                choices: vec![0, 1],
                gold: 0,
            })
            .collect();
        let packs = pack_items(&items, 4, 8);
        assert_eq!(packs.len(), 2);
        let all: Vec<usize> = packs.iter().flat_map(|(_, idx)| idx.clone()).collect();
        for i in 0..5 {
            assert!(all.contains(&i));
        }
    }
}

"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes/ranks; explicit cases pin the AOT shapes used by
the artifacts. All comparisons are against the pure-jnp oracles in
``compile.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    cur_linear,
    cur_linear_pallas,
    rmsnorm,
    rmsnorm_pallas,
    wanda_score,
    col_sumsq,
)
from compile.kernels.ref import (
    cur_linear_ref,
    wanda_score_ref,
    rmsnorm_ref,
    col_sumsq_ref,
)

jax.config.update("jax_platform_name", "cpu")


def rng(seed):
    return np.random.default_rng(seed)


def rand(r, *shape):
    return jnp.asarray(r.standard_normal(shape, dtype=np.float32))


# ---------------------------------------------------------------- cur_linear

@pytest.mark.parametrize(
    "t,m,rank,n",
    [
        (64, 256, 16, 256),    # tiny attention Q/K at default rank
        (128, 256, 16, 704),   # tiny gate projection
        (512, 256, 32, 256),   # full batch*seq, rank ablation upper
        (64, 256, 8, 704),     # rank ablation lower
        (7, 33, 4, 19),        # ragged fallback path
    ],
)
def test_cur_linear_matches_ref(t, m, rank, n):
    r_ = rng(t * 1000 + n)
    x, c, u, rr = rand(r_, t, m), rand(r_, m, rank), rand(r_, rank, rank), rand(r_, rank, n)
    got = cur_linear_pallas(x, c, u, rr)
    want = cur_linear_ref(x, c, u, rr)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 96),
    m=st.integers(1, 80),
    rank=st.integers(1, 24),
    n=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_cur_linear_hypothesis(t, m, rank, n, seed):
    r_ = rng(seed)
    x, c, u, rr = rand(r_, t, m), rand(r_, m, rank), rand(r_, rank, rank), rand(r_, rank, n)
    got = cur_linear_pallas(x, c, u, rr)
    want = cur_linear_ref(x, c, u, rr)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_cur_linear_vjp_matches_jnp_grads():
    """custom_vjp grads == autodiff of the reference chain."""
    r_ = rng(7)
    x, c, u, rr = rand(r_, 32, 40), rand(r_, 40, 8), rand(r_, 8, 8), rand(r_, 8, 24)

    def loss_kernel(x, c, u, rr):
        return jnp.sum(cur_linear(x, c, u, rr) ** 2)

    def loss_ref(x, c, u, rr):
        return jnp.sum(cur_linear_ref(x, c, u, rr) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2, 3))(x, c, u, rr)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x, c, u, rr)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_cur_linear_exact_when_full_rank_identity():
    """With C = I-columns covering all of W and U = C^+ W R^+, CUR at full
    rank reconstructs W exactly -> kernel output equals dense x @ w."""
    r_ = rng(3)
    m = n = 16
    w = rand(r_, m, n)
    c = w  # all columns
    rr = w  # all rows
    u = jnp.asarray(np.linalg.pinv(np.asarray(c)) @ np.asarray(w) @ np.linalg.pinv(np.asarray(rr)))
    x = rand(r_, 8, m)
    got = cur_linear_pallas(x, c, u, rr)
    np.testing.assert_allclose(got, x @ w, rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------------- rmsnorm

@pytest.mark.parametrize("t,d", [(64, 256), (512, 256), (5, 33)])
def test_rmsnorm_matches_ref(t, d):
    r_ = rng(t + d)
    x, w = rand(r_, t, d), rand(r_, d)
    np.testing.assert_allclose(
        rmsnorm_pallas(x, w), rmsnorm_ref(x, w), rtol=2e-4, atol=2e-4
    )


@settings(max_examples=20, deadline=None)
@given(t=st.integers(1, 80), d=st.integers(1, 96), seed=st.integers(0, 2**31 - 1))
def test_rmsnorm_hypothesis(t, d, seed):
    r_ = rng(seed)
    x, w = rand(r_, t, d), rand(r_, d)
    np.testing.assert_allclose(
        rmsnorm_pallas(x, w), rmsnorm_ref(x, w), rtol=5e-4, atol=5e-4
    )


def test_rmsnorm_grad_matches_ref():
    r_ = rng(11)
    x, w = rand(r_, 16, 32), rand(r_, 32)
    gk = jax.grad(lambda x, w: jnp.sum(rmsnorm(x, w) ** 2), argnums=(0, 1))(x, w)
    gr = jax.grad(lambda x, w: jnp.sum(rmsnorm_ref(x, w) ** 2), argnums=(0, 1))(x, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------------- wanda

@pytest.mark.parametrize("m,n", [(256, 256), (256, 704), (33, 17)])
def test_wanda_score_matches_ref(m, n):
    r_ = rng(m + n)
    w, xn = rand(r_, m, n), jnp.abs(rand(r_, m)) + 0.01
    np.testing.assert_allclose(
        wanda_score(w, xn), wanda_score_ref(w, xn), rtol=1e-5, atol=1e-6
    )


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 128), n=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_wanda_score_hypothesis(m, n, seed):
    r_ = rng(seed)
    w, xn = rand(r_, m, n), jnp.abs(rand(r_, m))
    np.testing.assert_allclose(
        wanda_score(w, xn), wanda_score_ref(w, xn), rtol=1e-5, atol=1e-6
    )


def test_wanda_score_nonnegative_and_zero_preserving():
    r_ = rng(5)
    w, xn = rand(r_, 32, 32), jnp.abs(rand(r_, 32))
    s = np.asarray(wanda_score(w, xn))
    assert (s >= 0).all()
    w0 = w.at[3].set(0.0)
    s0 = np.asarray(wanda_score(w0, xn))
    assert np.all(s0[3] == 0)


@pytest.mark.parametrize("t,m", [(64, 256), (512, 704), (3, 5)])
def test_col_sumsq_matches_ref(t, m):
    r_ = rng(t * 7 + m)
    x = rand(r_, t, m)
    np.testing.assert_allclose(col_sumsq(x), col_sumsq_ref(x), rtol=1e-4, atol=1e-4)

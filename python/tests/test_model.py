"""L2 model invariants: shapes, causality, CUR-exactness, losses, AdamW."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import ModelConfig

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(name="test", vocab=64, d_model=32, n_layers=4, n_heads=4,
                  d_inter=64, seq=16, batch=2, ranks=(4,), default_rank=4)


def rng(seed=0):
    return np.random.default_rng(seed)


def dense_layer_params(r, cfg, scale=0.05):
    d, di = cfg.d_model, cfg.d_inter
    def t(*shape):
        return jnp.asarray(r.standard_normal(shape, dtype=np.float32) * scale)
    return {
        "ln1": jnp.ones(d), "ln2": jnp.ones(d),
        "w_q": t(d, d), "w_k": t(d, d), "w_v": t(d, d), "w_o": t(d, d),
        "w_gate": t(d, di), "w_up": t(d, di), "w_down": t(di, d),
    }


def full_params(r, cfg):
    p = {"emb": jnp.asarray(r.standard_normal((cfg.vocab, cfg.d_model), dtype=np.float32) * 0.1),
         "ln_f": jnp.ones(cfg.d_model)}
    for l in range(cfg.n_layers):
        p[f"layer{l}"] = dense_layer_params(r, cfg)
    return p


def tokens(r, cfg):
    return jnp.asarray(r.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), dtype=jnp.int32)


# ------------------------------------------------------------------ shapes

def test_model_dense_logits_shape():
    r = rng(1)
    params = full_params(r, CFG)
    logits = M.model_dense_logits(tokens(r, CFG), params, CFG, use_pallas=False)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_block_preserves_shape_and_is_residual():
    r = rng(2)
    p = dense_layer_params(r, CFG, scale=0.0)  # zero weights
    x = jnp.asarray(r.standard_normal((CFG.batch, CFG.seq, CFG.d_model), dtype=np.float32))
    y = M.block(x, p, CFG, use_pallas=False)
    # With all-zero projections, the block is the identity (pure residual).
    np.testing.assert_allclose(y, x, rtol=1e-6)


# --------------------------------------------------------------- causality

def test_causal_masking():
    """Changing a future token must not change past NLL."""
    r = rng(3)
    params = full_params(r, CFG)
    toks = tokens(r, CFG)
    tgts = tokens(r, CFG)
    logits_a = M.model_dense_logits(toks, params, CFG, use_pallas=False)
    toks_b = toks.at[:, -1].set((toks[:, -1] + 1) % CFG.vocab)
    logits_b = M.model_dense_logits(toks_b, params, CFG, use_pallas=False)
    nll_a = M.nll_from_logits(logits_a, tgts)
    nll_b = M.nll_from_logits(logits_b, tgts)
    np.testing.assert_allclose(nll_a[:, :-1], nll_b[:, :-1], rtol=1e-5, atol=1e-6)
    # And the last position does change (the model is not degenerate).
    assert not np.allclose(nll_a[:, -1], nll_b[:, -1])


# ----------------------------------------------------------- CUR exactness

def test_cured_block_exact_at_full_rank():
    """CUR with C/R = all columns/rows and U = C^+ W R^+ reconstructs the
    dense block bit-near-exactly (the paper's lossless limit)."""
    r = rng(4)
    p = dense_layer_params(r, CFG)
    x = jnp.asarray(r.standard_normal((CFG.batch, CFG.seq, CFG.d_model), dtype=np.float32))
    y_dense = M.block(x, p, CFG, use_pallas=False)
    pc = dict(p)
    for name in ("q", "k", "gate"):
        w = np.asarray(p[f"w_{name}"])
        u = np.linalg.pinv(w) @ w @ np.linalg.pinv(w)
        del pc[f"w_{name}"]
        pc[f"c_{name}"] = jnp.asarray(w)
        pc[f"u_{name}"] = jnp.asarray(u.astype(np.float32))
        pc[f"r_{name}"] = jnp.asarray(w)
    y_cur = M.block(x, pc, CFG, use_pallas=False)
    np.testing.assert_allclose(y_cur, y_dense, rtol=2e-3, atol=2e-3)


def test_switched_block_blends():
    """switch=0 -> dense path; switch=1 -> CUR path."""
    r = rng(5)
    p = dense_layer_params(r, CFG)
    rk = 4
    def t(*shape):
        return jnp.asarray(r.standard_normal(shape, dtype=np.float32) * 0.05)
    for name, n_out in [("q", CFG.d_model), ("k", CFG.d_model), ("gate", CFG.d_inter)]:
        p[f"c_{name}"] = t(CFG.d_model, rk)
        p[f"u_{name}"] = t(rk, rk)
        p[f"du_{name}"] = jnp.zeros((rk, rk))
        p[f"r_{name}"] = t(rk, n_out)
    x = jnp.asarray(r.standard_normal((CFG.batch, CFG.seq, CFG.d_model), dtype=np.float32))
    y0 = M.block_switched(x, p, 0.0, CFG, use_pallas=False)
    y_dense = M.block(x, {k: v for k, v in p.items()
                          if not k.startswith(("c_", "u_", "du_", "r_"))}, CFG, use_pallas=False)
    np.testing.assert_allclose(y0, y_dense, rtol=1e-5, atol=1e-6)
    y1 = M.block_switched(x, p, 1.0, CFG, use_pallas=False)
    pc = dict(p)
    for name in ("q", "k", "gate"):
        del pc[f"w_{name}"]
    y_cur = M.block(x, pc, CFG, use_pallas=False)
    np.testing.assert_allclose(y1, y_cur, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------ losses

def test_kd_loss_zero_when_identical():
    r = rng(6)
    logits = jnp.asarray(r.standard_normal((2, 4, 8), dtype=np.float32))
    assert abs(float(M.kd_loss(logits, logits, 10.0))) < 1e-5


def test_kd_loss_positive_when_different():
    r = rng(7)
    a = jnp.asarray(r.standard_normal((2, 4, 8), dtype=np.float32))
    b = jnp.asarray(r.standard_normal((2, 4, 8), dtype=np.float32))
    assert float(M.kd_loss(a, b, 10.0)) > 0


def test_ce_loss_weighted_mask():
    r = rng(8)
    logits = jnp.asarray(r.standard_normal((1, 4, 8), dtype=np.float32))
    targets = jnp.asarray([[1, 2, 3, 4]], dtype=jnp.int32)
    w = jnp.asarray([[0.0, 0.0, 1.0, 0.0]])
    masked = float(M.ce_loss(logits, targets, w))
    nll = M.nll_from_logits(logits, targets)
    assert abs(masked - float(nll[0, 2])) < 1e-5


# ------------------------------------------------------------------- adamw

def test_adamw_converges_quadratic():
    p = jnp.asarray(5.0)
    m = jnp.asarray(0.0)
    v = jnp.asarray(0.0)
    for t in range(1, 300):
        g = 2.0 * p  # d/dp p^2
        p, m, v = M.adamw_update(p, g, m, v, 0.05, float(t), 0.0)
    assert abs(float(p)) < 0.1


def test_adamw_weight_decay_shrinks_params():
    p = jnp.asarray(1.0)
    m = jnp.asarray(0.0)
    v = jnp.asarray(0.0)
    p2, _, _ = M.adamw_update(p, jnp.asarray(0.0), m, v, 0.1, 1.0, 0.5)
    assert float(p2) < 1.0


# ---------------------------------------------------------------- adapters

def test_mora_adapter_shapes_and_zero_init_inert():
    r = rng(9)
    p = dense_layer_params(r, CFG)
    rm = 4
    p["mora_m_q"] = jnp.zeros((rm, rm))
    x = jnp.asarray(r.standard_normal((CFG.batch, CFG.seq, CFG.d_model), dtype=np.float32))
    with_adapter = M.proj(x, p, "q", use_pallas=False)
    del p["mora_m_q"]
    without = M.proj(x, p, "q", use_pallas=False)
    np.testing.assert_allclose(with_adapter, without, rtol=1e-6)


def test_lora_adapter_contributes_when_nonzero():
    r = rng(10)
    p = dense_layer_params(r, CFG)
    p["lora_a_q"] = jnp.asarray(r.standard_normal((CFG.d_model, 2), dtype=np.float32))
    p["lora_b_q"] = jnp.asarray(r.standard_normal((2, CFG.d_model), dtype=np.float32))
    x = jnp.asarray(r.standard_normal((CFG.batch, CFG.seq, CFG.d_model), dtype=np.float32))
    with_adapter = M.proj(x, p, "q", use_pallas=False)
    del p["lora_a_q"], p["lora_b_q"]
    without = M.proj(x, p, "q", use_pallas=False)
    assert not np.allclose(with_adapter, without)


# -------------------------------------------------------------------- rope

def test_rope_preserves_norm():
    r = rng(11)
    cos, sin = M.rope_tables(CFG.seq, CFG.d_k, CFG.rope_theta)
    x = jnp.asarray(
        r.standard_normal((1, CFG.seq, CFG.n_heads, CFG.d_k), dtype=np.float32)
    )
    y = M.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-4,
    )


def test_rope_position_zero_is_identity():
    r = rng(12)
    cos, sin = M.rope_tables(CFG.seq, CFG.d_k, CFG.rope_theta)
    x = jnp.asarray(
        r.standard_normal((1, CFG.seq, CFG.n_heads, CFG.d_k), dtype=np.float32)
    )
    y = M.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.asarray(y)[0, 0], np.asarray(x)[0, 0], rtol=1e-5)

"""AOT lowering driver: every artifact the Rust coordinator executes.

Emits HLO **text** (NOT ``.serialize()``): the image's xla_extension 0.5.1
rejects jax>=0.5 protos with 64-bit instruction ids; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is a (signature, function) pair. The signature is an ordered
list of named specs; ``artifacts/manifest.json`` records names, shapes,
dtypes and output layout so the Rust side marshals generically. Run via
``make artifacts`` (no-op when inputs are unchanged).
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import CONFIGS, COMBOS

F32, I32 = "f32", "i32"


class Sig:
    """Ordered named input signature for one artifact."""

    def __init__(self):
        self.entries = []  # (name, shape tuple, dtype str)

    def add(self, name, shape, dtype=F32):
        self.entries.append((name, tuple(int(x) for x in shape), dtype))
        return self

    def specs(self):
        return [
            jax.ShapeDtypeStruct(s, jnp.int32 if d == I32 else jnp.float32)
            for (_, s, d) in self.entries
        ]

    def index(self):
        return {n: i for i, (n, _, _) in enumerate(self.entries)}


# ------------------------------------------------------- signature builders


def add_dense_layer(sig, cfg, pre):
    d, di = cfg.d_model, cfg.d_inter
    sig.add(f"{pre}.ln1", (d,))
    sig.add(f"{pre}.w_q", (d, d))
    sig.add(f"{pre}.w_k", (d, d))
    sig.add(f"{pre}.w_v", (d, d))
    sig.add(f"{pre}.w_o", (d, d))
    sig.add(f"{pre}.ln2", (d,))
    sig.add(f"{pre}.w_gate", (d, di))
    sig.add(f"{pre}.w_up", (d, di))
    sig.add(f"{pre}.w_down", (di, d))


def add_cured_layer(sig, cfg, pre, rank, combo, split_u=False):
    """Cured layer: targeted weights replaced by (c, u[, du], r)."""
    d, di = cfg.d_model, cfg.d_inter
    targets = COMBOS[combo]
    dims = {"q": (d, d), "k": (d, d), "gate": (d, di)}

    sig.add(f"{pre}.ln1", (d,))
    for name in ("q", "k"):
        m, n = dims[name]
        if name in targets:
            sig.add(f"{pre}.c_{name}", (m, rank))
            sig.add(f"{pre}.u_{name}", (rank, rank))
            if split_u:
                sig.add(f"{pre}.du_{name}", (rank, rank))
            sig.add(f"{pre}.r_{name}", (rank, n))
        else:
            sig.add(f"{pre}.w_{name}", (m, n))
    sig.add(f"{pre}.w_v", (d, d))
    sig.add(f"{pre}.w_o", (d, d))
    sig.add(f"{pre}.ln2", (d,))
    if "gate" in targets:
        sig.add(f"{pre}.c_gate", (d, rank))
        sig.add(f"{pre}.u_gate", (rank, rank))
        if split_u:
            sig.add(f"{pre}.du_gate", (rank, rank))
        sig.add(f"{pre}.r_gate", (rank, di))
    else:
        sig.add(f"{pre}.w_gate", (d, di))
    sig.add(f"{pre}.w_up", (d, di))
    sig.add(f"{pre}.w_down", (di, d))


def add_switched_layer(sig, cfg, pre, rank, adapter=None):
    """Middle layer of a full-model artifact: dense + CUR + optional
    adapter parameters, runtime-blended by the switch vector."""
    d, di = cfg.d_model, cfg.d_inter
    dims = {"q": (d, d), "k": (d, d), "gate": (d, di)}
    sig.add(f"{pre}.ln1", (d,))
    order = ["q", "k", "v", "o"]
    for name in order:
        m, n = (d, d)
        sig.add(f"{pre}.w_{name}", (m, n))
        if name in ("q", "k"):
            sig.add(f"{pre}.c_{name}", (d, rank))
            sig.add(f"{pre}.u_{name}", (rank, rank))
            sig.add(f"{pre}.du_{name}", (rank, rank))
            sig.add(f"{pre}.r_{name}", (rank, d))
    sig.add(f"{pre}.ln2", (d,))
    sig.add(f"{pre}.w_gate", (d, di))
    sig.add(f"{pre}.c_gate", (d, rank))
    sig.add(f"{pre}.u_gate", (rank, rank))
    sig.add(f"{pre}.du_gate", (rank, rank))
    sig.add(f"{pre}.r_gate", (rank, di))
    sig.add(f"{pre}.w_up", (d, di))
    sig.add(f"{pre}.w_down", (di, d))
    for name in ("q", "k", "gate"):
        m, n = dims[name]
        if adapter == "lora":
            rl = cfg.lora_rank
            sig.add(f"{pre}.lora_a_{name}", (m, rl))
            sig.add(f"{pre}.lora_b_{name}", (rl, n))
        elif adapter == "mora":
            rm = cfg.mora_rank
            sig.add(f"{pre}.mora_m_{name}", (rm, rm))
        elif adapter == "curlora":
            rc = cfg.default_rank
            sig.add(f"{pre}.cl_c_{name}", (m, rc))
            sig.add(f"{pre}.cl_u_{name}", (rc, rc))
            sig.add(f"{pre}.cl_r_{name}", (rc, n))


def layer_dict(args, idx, pre):
    """Split flat args back into one layer's param dict (keys stripped)."""
    p = {}
    plen = len(pre) + 1
    for name, i in idx.items():
        if name.startswith(pre + "."):
            p[name[plen:]] = args[i]
    return p


# ------------------------------------------------------- artifact builders


def art_embed(cfg):
    sig = Sig()
    sig.add("tokens", (cfg.batch, cfg.seq), I32)
    sig.add("emb", (cfg.vocab, cfg.d_model))

    def fn(tokens, emb):
        return (M.embed(tokens, emb),)

    return sig, fn, ["x"]


def art_layer_dense(cfg):
    sig = Sig()
    sig.add("x", (cfg.batch, cfg.seq, cfg.d_model))
    add_dense_layer(sig, cfg, "L")
    idx = sig.index()

    def fn(*args):
        p = layer_dict(args, idx, "L")
        return (M.block(args[0], p, cfg, use_pallas=True),)

    return sig, fn, ["y"]


def art_layer_calib(cfg):
    sig = Sig()
    sig.add("x", (cfg.batch, cfg.seq, cfg.d_model))
    add_dense_layer(sig, cfg, "L")
    idx = sig.index()

    def fn(*args):
        p = layer_dict(args, idx, "L")
        y, a_ss, f_ss, attn_in, ffn_in = M.block_calib(args[0], p, cfg)
        return (y, a_ss, f_ss, attn_in, ffn_in)

    return sig, fn, ["y", "attn_sumsq", "ffn_sumsq", "attn_in", "ffn_in"]


def art_layer_cured(cfg, rank, combo):
    sig = Sig()
    sig.add("x", (cfg.batch, cfg.seq, cfg.d_model))
    add_cured_layer(sig, cfg, "L", rank, combo)
    idx = sig.index()

    def fn(*args):
        p = layer_dict(args, idx, "L")
        return (M.block(args[0], p, cfg, use_pallas=True),)

    return sig, fn, ["y"]


def art_head_nll(cfg):
    sig = Sig()
    sig.add("x", (cfg.batch, cfg.seq, cfg.d_model))
    sig.add("ln_f", (cfg.d_model,))
    sig.add("emb", (cfg.vocab, cfg.d_model))
    sig.add("targets", (cfg.batch, cfg.seq), I32)

    def fn(x, ln_f, emb, targets):
        return (M.head_nll(x, ln_f, emb, targets),)

    return sig, fn, ["nll"]


def art_head_logits(cfg):
    sig = Sig()
    sig.add("x", (cfg.batch, cfg.seq, cfg.d_model))
    sig.add("ln_f", (cfg.d_model,))
    sig.add("emb", (cfg.vocab, cfg.d_model))

    def fn(x, ln_f, emb):
        return (M.head_logits(x, ln_f, emb),)

    return sig, fn, ["logits"]


def full_param_names(cfg):
    names = ["emb", "ln_f"]
    return names


def art_train_step_dense(cfg):
    """Full-model LM pretraining step: CE loss + inline AdamW.

    Creates the 'original model' that every experiment compresses.
    """
    sig = Sig()
    sig.add("tokens", (cfg.batch, cfg.seq), I32)
    sig.add("targets", (cfg.batch, cfg.seq), I32)
    sig.add("lr", ())
    sig.add("t", ())
    pstart = len(sig.entries)
    sig.add("emb", (cfg.vocab, cfg.d_model))
    for l in range(cfg.n_layers):
        add_dense_layer(sig, cfg, f"L{l}")
    sig.add("ln_f", (cfg.d_model,))
    pend = len(sig.entries)
    pnames = [n for (n, _, _) in sig.entries[pstart:pend]]
    for n, s, _ in list(sig.entries[pstart:pend]):
        sig.add(f"m.{n}", s)
    for n, s, _ in list(sig.entries[pstart:pend]):
        sig.add(f"v.{n}", s)
    idx = sig.index()

    def params_of(args):
        params = {"emb": args[idx["emb"]], "ln_f": args[idx["ln_f"]]}
        for l in range(cfg.n_layers):
            params[f"layer{l}"] = layer_dict(args, idx, f"L{l}")
        return params

    def fn(*args):
        tokens, targets = args[idx["tokens"]], args[idx["targets"]]
        lr, t = args[idx["lr"]], args[idx["t"]]
        flat = {n: args[idx[n]] for n in pnames}
        ms = {n: args[idx[f"m.{n}"]] for n in pnames}
        vs = {n: args[idx[f"v.{n}"]] for n in pnames}

        def loss_fn(flat_params):
            params = {"emb": flat_params["emb"], "ln_f": flat_params["ln_f"]}
            for l in range(cfg.n_layers):
                params[f"layer{l}"] = {
                    k[len(f"L{l}."):]: v
                    for k, v in flat_params.items()
                    if k.startswith(f"L{l}.")
                }
            logits = M.model_dense_logits(tokens, params, cfg, use_pallas=False)
            return M.ce_loss(logits, targets)

        loss, grads = jax.value_and_grad(loss_fn)(flat)
        new_p, new_m, new_v = M.sgd_like_tree_adamw(flat, grads, ms, vs, lr, t, 0.01)
        out = [loss]
        out += [new_p[n] for n in pnames]
        out += [new_m[n] for n in pnames]
        out += [new_v[n] for n in pnames]
        return tuple(out)

    outs = ["loss"] + pnames + [f"m.{n}" for n in pnames] + [f"v.{n}" for n in pnames]
    return sig, fn, outs


def art_layer_heal_step(cfg, rank):
    """Per-layer KD healing (paper §4.5): MSE between the teacher's layer
    output and the cured layer's output; AdamW on dU^Q, dU^K, dU^Gate
    only. Also returns the student's (pre-update) output so the Rust
    driver can propagate the *student's* running hidden state to the next
    layer — drift-correcting layer-wise distillation: each cured layer
    learns to map the student state back onto the teacher trajectory."""
    sig = Sig()
    sig.add("x", (cfg.batch, cfg.seq, cfg.d_model))
    sig.add("y_teacher", (cfg.batch, cfg.seq, cfg.d_model))
    sig.add("lr", ())
    sig.add("t", ())
    add_cured_layer(sig, cfg, "L", rank, "all", split_u=True)
    tr = ["du_q", "du_k", "du_gate"]
    for n in tr:
        sig.add(f"m.{n}", (rank, rank))
    for n in tr:
        sig.add(f"v.{n}", (rank, rank))
    idx = sig.index()

    def fn(*args):
        x, y_t = args[idx["x"]], args[idx["y_teacher"]]
        lr, t = args[idx["lr"]], args[idx["t"]]
        p = layer_dict(args, idx, "L")
        dus = {n: p[n] for n in tr}
        frozen = {k: v for k, v in p.items() if k not in tr}
        ms = {n: args[idx[f"m.{n}"]] for n in tr}
        vs = {n: args[idx[f"v.{n}"]] for n in tr}

        def loss_fn(dus):
            y = M.block(x, {**frozen, **dus}, cfg, use_pallas=True)
            diff = y - y_t
            return jnp.mean(diff * diff), y

        (loss, y), grads = jax.value_and_grad(loss_fn, has_aux=True)(dus)
        new_p, new_m, new_v = M.sgd_like_tree_adamw(dus, grads, ms, vs, lr, t, 0.0)
        out = [loss, y]
        out += [new_p[n] for n in tr]
        out += [new_m[n] for n in tr]
        out += [new_v[n] for n in tr]
        return tuple(out)

    outs = ["loss", "y_student"] + tr + [f"m.{n}" for n in tr] + [f"v.{n}" for n in tr]
    return sig, fn, outs


def switched_sig(cfg, rank, adapter=None):
    """Common input block for full-model switched artifacts."""
    sig = Sig()
    sig.add("tokens", (cfg.batch, cfg.seq), I32)
    sig.add("targets", (cfg.batch, cfg.seq), I32)
    sig.add("switches", (cfg.n_layers,))
    sig.add("emb", (cfg.vocab, cfg.d_model))
    mids = set(M.middle_layers(cfg))
    for l in range(cfg.n_layers):
        if l in mids:
            add_switched_layer(sig, cfg, f"L{l}", rank, adapter)
        else:
            add_dense_layer(sig, cfg, f"L{l}")
    sig.add("ln_f", (cfg.d_model,))
    return sig


def trainable_names(cfg, adapter):
    """Flat names of the trainable set for a given adapter kind."""
    mids = M.middle_layers(cfg)
    names = []
    for l in mids:
        for w in ("q", "k", "gate"):
            if adapter == "du":
                names.append(f"L{l}.du_{w}")
            elif adapter == "lora":
                names.append(f"L{l}.lora_a_{w}")
                names.append(f"L{l}.lora_b_{w}")
            elif adapter == "mora":
                names.append(f"L{l}.mora_m_{w}")
            elif adapter == "curlora":
                names.append(f"L{l}.cl_u_{w}")
    return names


def switched_params_of(args, idx, cfg):
    params = {"emb": args[idx["emb"]], "ln_f": args[idx["ln_f"]]}
    for l in range(cfg.n_layers):
        params[f"layer{l}"] = layer_dict(args, idx, f"L{l}")
    return params


def dense_view(params, cfg):
    """Strip CUR/adapter entries so the same args act as the teacher."""
    dense_keys = {"ln1", "w_q", "w_k", "w_v", "w_o", "ln2", "w_gate", "w_up", "w_down"}
    out = {"emb": params["emb"], "ln_f": params["ln_f"]}
    for l in range(cfg.n_layers):
        out[f"layer{l}"] = {
            k: v for k, v in params[f"layer{l}"].items() if k in dense_keys
        }
    return out


def make_switched_step(cfg, rank, adapter, mode):
    """Full-model training step; mode 'heal' (0.9*KD + 0.1*CE, teacher
    computed in-graph from the dense weights) or 'task' (masked CE)."""
    adapter_in_sig = None if adapter in ("du",) else adapter
    sig = switched_sig(cfg, rank, adapter_in_sig)
    if mode == "task":
        sig.add("loss_mask", (cfg.batch, cfg.seq))
    sig.add("lr", ())
    sig.add("t", ())
    tr = trainable_names(cfg, adapter)
    shape_of = {n: s for (n, s, _) in sig.entries}
    for n in tr:
        sig.add(f"m.{n}", shape_of[n])
    for n in tr:
        sig.add(f"v.{n}", shape_of[n])
    idx = sig.index()

    def fn(*args):
        tokens, targets = args[idx["tokens"]], args[idx["targets"]]
        switches = args[idx["switches"]]
        lr, t = args[idx["lr"]], args[idx["t"]]
        ms = {n: args[idx[f"m.{n}"]] for n in tr}
        vs = {n: args[idx[f"v.{n}"]] for n in tr}
        base = switched_params_of(args, idx, cfg)
        trainables = {}
        for n in tr:
            l = int(n[1 : n.index(".")])
            key = n.split(".", 1)[1]
            trainables[n] = base[f"layer{l}"].pop(key)

        def loss_fn(trainables):
            params = {k: (dict(v) if isinstance(v, dict) else v) for k, v in base.items()}
            for n, val in trainables.items():
                l = int(n[1 : n.index(".")])
                key = n.split(".", 1)[1]
                params[f"layer{l}"][key] = val
            logits = M.model_switched_logits(tokens, params, switches, cfg, use_pallas=False)
            if mode == "heal":
                teacher = M.model_dense_logits(tokens, dense_view(params, cfg), cfg, use_pallas=False)
                teacher = jax.lax.stop_gradient(teacher)
                return 0.1 * M.ce_loss(logits, targets) + 0.9 * M.kd_loss(logits, teacher, 10.0)
            mask = args[idx["loss_mask"]]
            return M.ce_loss(logits, targets, weights=mask)

        loss, grads = jax.value_and_grad(loss_fn)(trainables)
        new_p, new_m, new_v = M.sgd_like_tree_adamw(trainables, grads, ms, vs, lr, t, 0.0)
        out = [loss]
        out += [new_p[n] for n in tr]
        out += [new_m[n] for n in tr]
        out += [new_v[n] for n in tr]
        return tuple(out)

    outs = ["loss"] + tr + [f"m.{n}" for n in tr] + [f"v.{n}" for n in tr]
    return sig, fn, outs


def art_model_logits_switched(cfg, rank, adapter):
    """Forward-only switched model WITH adapter parameters, returning
    logits — the evaluation path for PEFT-adapted models (Figs. 5-7):
    task accuracy and shifted-corpus perplexity are computed from these
    logits by the Rust coordinator."""
    adapter_in_sig = None if adapter in (None, "du") else adapter
    sig = switched_sig(cfg, rank, adapter_in_sig)
    idx = sig.index()

    def fn(*args):
        tokens = args[idx["tokens"]]
        switches = args[idx["switches"]]
        params = switched_params_of(args, idx, cfg)
        logits = M.model_switched_logits(tokens, params, switches, cfg, use_pallas=True)
        return (logits,)

    return sig, fn, ["logits"]


def art_model_nll_switched(cfg, rank):
    """Forward-only switched model returning per-token NLL — used to
    cross-check the Rust per-layer pipeline against a monolithic program,
    and for fast full-model perplexity probes during PEFT runs."""
    sig = switched_sig(cfg, rank, None)
    idx = sig.index()

    def fn(*args):
        tokens, targets = args[idx["tokens"]], args[idx["targets"]]
        switches = args[idx["switches"]]
        params = switched_params_of(args, idx, cfg)
        logits = M.model_switched_logits(tokens, params, switches, cfg, use_pallas=True)
        return (M.nll_from_logits(logits, targets),)

    return sig, fn, ["nll"]


# ----------------------------------------------------------------- driver


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_artifact_table(cfg):
    arts = {}
    arts[f"{cfg.name}_embed_fwd"] = art_embed(cfg)
    arts[f"{cfg.name}_layer_fwd_dense"] = art_layer_dense(cfg)
    arts[f"{cfg.name}_layer_fwd_calib"] = art_layer_calib(cfg)
    arts[f"{cfg.name}_head_nll"] = art_head_nll(cfg)
    arts[f"{cfg.name}_head_logits"] = art_head_logits(cfg)
    for r in cfg.ranks:
        for combo in COMBOS:
            arts[f"{cfg.name}_layer_fwd_cured_r{r}_c{combo}"] = art_layer_cured(cfg, r, combo)
        arts[f"{cfg.name}_layer_heal_step_r{r}"] = art_layer_heal_step(cfg, r)
    if cfg.full_model_artifacts:
        arts[f"{cfg.name}_train_step_dense"] = art_train_step_dense(cfg)
        arts[f"{cfg.name}_model_nll_switched"] = art_model_nll_switched(cfg, cfg.default_rank)
        for adapter in ("du", "lora", "mora"):
            arts[f"{cfg.name}_heal_full_{adapter}"] = make_switched_step(
                cfg, cfg.default_rank, adapter, "heal"
            )
        for adapter in ("du", "lora", "mora", "curlora"):
            arts[f"{cfg.name}_task_step_{adapter}"] = make_switched_step(
                cfg, cfg.default_rank, adapter, "task"
            )
            arts[f"{cfg.name}_model_logits_switched_{adapter}"] = art_model_logits_switched(
                cfg, cfg.default_rank, adapter
            )
    return arts


def source_fingerprint():
    """Hash of the compile package sources; stored in the manifest so
    `make artifacts` can skip rebuilds when nothing changed."""
    h = hashlib.sha256()
    pkg = os.path.dirname(__file__)
    for root, _, files in sorted(os.walk(pkg)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,base")
    ap.add_argument("--only", default=None, help="comma-sep artifact name filter")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {"fingerprint": source_fingerprint(), "configs": {}, "artifacts": {}}
    mpath = os.path.join(args.out, "manifest.json")
    if os.path.exists(mpath) and args.only is None:
        with open(mpath) as f:
            try:
                old = json.load(f)
            except ValueError:
                old = {}
        if old.get("fingerprint") == manifest["fingerprint"]:
            print("artifacts up to date (fingerprint match); skipping")
            return

    for cname in args.configs.split(","):
        cfg = CONFIGS[cname]
        manifest["configs"][cname] = {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_inter": cfg.d_inter,
            "seq": cfg.seq,
            "batch": cfg.batch,
            "ranks": list(cfg.ranks),
            "default_rank": cfg.default_rank,
            "lora_rank": cfg.lora_rank,
            "mora_rank": cfg.mora_rank,
            "rope_theta": cfg.rope_theta,
            "total_params": cfg.total_params(),
        }
        arts = build_artifact_table(cfg)
        for name, (sig, fn, out_names) in arts.items():
            if args.only and name not in args.only.split(","):
                continue
            fname = f"{name}.hlo.txt"
            print(f"lowering {name} ({len(sig.entries)} inputs) ...", flush=True)
            # keep_unused=True: the manifest promises every declared input
            # is a real HLO parameter (jit would otherwise prune inputs an
            # artifact ignores — e.g. `targets` in logits-only programs —
            # and PJRT would reject the coordinator's buffer count).
            lowered = jax.jit(fn, keep_unused=True).lower(*sig.specs())
            text = to_hlo_text(lowered)
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(text)
            # Output shapes from the lowered signature.
            out_avals = lowered.out_info
            out_meta = []
            leaves = jax.tree_util.tree_leaves(out_avals)
            for oname, aval in zip(out_names, leaves):
                dt = I32 if str(aval.dtype).startswith("int") else F32
                out_meta.append({"name": oname, "shape": list(aval.shape), "dtype": dt})
            manifest["artifacts"][name] = {
                "config": cname,
                "file": fname,
                "inputs": [
                    {"name": n, "shape": list(s), "dtype": d} for (n, s, d) in sig.entries
                ],
                "outputs": out_meta,
            }

    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()

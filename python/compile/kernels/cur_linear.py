"""Pallas kernel for the CURed linear layer — the paper's compute hot-spot.

The deployed CURing model never holds the dense ``m x n`` weight; every
compressed projection is the chain ``Y = ((X @ C) @ U) @ R`` with
``rank << min(m, n)``. This module implements that chain as a tiled Pallas
kernel and wraps it in ``jax.custom_vjp`` (forward = Pallas, backward =
pure jnp from ``ref.py``'s math) so the very same kernel sits inside both
inference and healing/fine-tuning artifacts.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks token tiles
(``bt`` rows of X); C, U and the row panel of R stay resident in VMEM
across the token axis, and all three contractions feed the MXU. The rank
is a power of two (paper Eq. 2), keeping MXU tiles full. ``interpret=True``
everywhere: the CPU PJRT plugin cannot execute Mosaic custom-calls, and
interpret-mode lowering inlines the kernel as plain HLO at trace time
(zero runtime interpretation cost after AOT).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cur_linear", "cur_linear_pallas", "DEFAULT_BLOCK_T"]

# Token-tile height. 64 keeps the (bt, m) input tile and the (bt, n) output
# tile comfortably inside VMEM for every config in configs.py while still
# filling an MXU pass; it also divides every batch*seq we emit.
DEFAULT_BLOCK_T = 64


def _cur_linear_kernel(x_ref, c_ref, u_ref, r_ref, o_ref):
    """One token tile: ``o = ((x @ C) @ U) @ R`` with rank-sized temps.

    The two intermediates are ``(bt, r)`` — tiny, register/VMEM resident.
    """
    xc = jnp.dot(x_ref[...], c_ref[...], preferred_element_type=jnp.float32)
    xcu = jnp.dot(xc, u_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(xcu, r_ref[...], preferred_element_type=jnp.float32)


def cur_linear_pallas(x, c, u, r, *, block_t=DEFAULT_BLOCK_T):
    """Raw Pallas forward (no vjp). ``x: (t, m)``, returns ``(t, n)``.

    The grid is 1-D over token tiles; C/U/R use ``None`` block axes so
    Pallas keeps them whole in VMEM for every grid step.
    """
    t, m = x.shape
    rank = c.shape[1]
    n = r.shape[1]
    bt = min(block_t, t)
    if t % bt != 0:
        # Fall back to a single-program kernel for ragged token counts
        # (only hit by tests; AOT shapes are always multiples of bt).
        bt = t
    grid = (t // bt,)
    return pl.pallas_call(
        _cur_linear_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, m), lambda i: (i, 0)),
            pl.BlockSpec((m, rank), lambda i: (0, 0)),
            pl.BlockSpec((rank, rank), lambda i: (0, 0)),
            pl.BlockSpec((rank, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=True,
    )(x, c, u, r)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def cur_linear(x, c, u, r):
    """CURed linear with custom vjp: forward = Pallas, backward = jnp.

    Gradients flow to all four operands; the healing artifacts simply
    freeze C/R/U0 and apply updates to dU only.
    """
    return cur_linear_pallas(x, c, u, r)


def _fwd(x, c, u, r):
    return cur_linear_pallas(x, c, u, r), (x, c, u, r)


def _bwd(res, gy):
    x, c, u, r = res
    # Chain-rule through Y = X C U R, computed in rank-sized pieces.
    xc = x @ c                    # (t, rank)
    gyr = gy @ r.T                # (t, rank)
    gx = (gyr @ u.T) @ c.T        # (t, m)
    gc = x.T @ (gyr @ u.T)        # (m, rank)
    gu = xc.T @ gyr               # (rank, rank)
    gr = (xc @ u).T @ gy          # (rank, n)
    return gx, gc, gu, gr


cur_linear.defvjp(_fwd, _bwd)

"""Pallas kernels for the WANDA importance statistics (paper §4.2, Fig 2a).

Two kernels:

* ``wanda_score`` — the information matrix ``S = |W| * xnorm[:, None]``
  combining weight magnitude with calibration activation norms. The Rust
  coordinator runs the SVD+DEIM selection on S; this kernel is exported as
  its own artifact so the scoring of large weights happens on-device.
* ``col_sumsq`` — per-input-feature sum of squares of an activation batch,
  the quantity accumulated during calibration (the coordinator adds across
  batches and takes the square root at the end).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["wanda_score", "col_sumsq"]


def _score_kernel(w_ref, xn_ref, s_ref):
    s_ref[...] = jnp.abs(w_ref[...]) * xn_ref[...][:, None]


def wanda_score(w, xnorm, *, block_m=128):
    """``S[i, j] = |W[i, j]| * xnorm[i]`` with a 1-D grid over input rows.

    ``w: (m, n)`` input-major, ``xnorm: (m,)``.
    """
    m, n = w.shape
    bm = min(block_m, m)
    if m % bm != 0:
        bm = m
    return pl.pallas_call(
        _score_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(w, xnorm)


def _sumsq_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = jnp.sum(x * x, axis=0)


def col_sumsq(x):
    """Sum over tokens of ``x**2`` per feature; single-program kernel.

    ``x: (t, m)`` -> ``(m,)``. The calibration batch is small (tokens of
    one forward pass), so one program holding the tile in VMEM suffices.
    """
    t, m = x.shape
    return pl.pallas_call(
        _sumsq_kernel,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(x)

"""L1 — Pallas kernels for CURing's compute hot-spots.

All kernels lower with ``interpret=True`` (CPU PJRT cannot run Mosaic
custom-calls; interpret lowering inlines plain HLO at trace time). Each
kernel has a pure-jnp oracle in :mod:`ref` that pytest/hypothesis compare
against, and custom_vjp wrappers use the oracle math for backward.
"""

from .cur_linear import cur_linear, cur_linear_pallas, DEFAULT_BLOCK_T
from .rmsnorm import rmsnorm, rmsnorm_pallas
from .wanda import wanda_score, col_sumsq
from . import ref

__all__ = [
    "cur_linear",
    "cur_linear_pallas",
    "rmsnorm",
    "rmsnorm_pallas",
    "wanda_score",
    "col_sumsq",
    "ref",
    "DEFAULT_BLOCK_T",
]

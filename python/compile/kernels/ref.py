"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal: pytest (and hypothesis sweeps)
assert the Pallas kernels match these references to float tolerance.
They are also used as the backward-pass implementations inside
``jax.custom_vjp`` wrappers, so training artifacts differentiate through
mathematically-identical jnp code while the forward pass runs the kernel.
"""

import jax.numpy as jnp

__all__ = [
    "cur_linear_ref",
    "wanda_score_ref",
    "rmsnorm_ref",
    "col_sumsq_ref",
    "silu_gate_ref",
]


def cur_linear_ref(x, c, u, r):
    """Reference CUR-factorized linear: ``Y = ((X @ C) @ U) @ R``.

    Never materializes the implied dense ``m x n`` product — the whole
    point of CURing is that this chain is the deployed compute path.

    Args:
      x: ``(t, m)`` input activations (tokens flattened over batch*seq).
      c: ``(m, r)`` selected columns of the original weight.
      u: ``(r, r)`` linking matrix (``U0 + dU`` after healing).
      r: ``(r, n)`` selected rows of the original weight.

    Returns:
      ``(t, n)`` output activations.
    """
    return ((x @ c) @ u) @ r


def wanda_score_ref(w, xnorm):
    """Reference WANDA importance: ``S[i, j] = |W[i, j]| * xnorm[i]``.

    ``w`` is stored input-major ``(m_in, n_out)`` (the model computes
    ``x @ w``), so the activation norm of input feature ``i`` scales row
    ``i``. This is the information matrix S of paper Fig. 2a.
    """
    return jnp.abs(w) * xnorm[:, None]


def rmsnorm_ref(x, w, eps=1e-5):
    """Reference RMSNorm: ``y = x * rsqrt(mean(x^2) + eps) * w``."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jnp.reciprocal(jnp.sqrt(ms + eps)) * w


def col_sumsq_ref(x):
    """Per-input-feature sum of squares over all tokens: ``(m,)``.

    Accumulated across calibration batches by the Rust coordinator and
    square-rooted there to form the WANDA ``xnorm`` vector.
    """
    return jnp.sum(x * x, axis=0)


def silu_gate_ref(g, up):
    """Reference SiLU-gated product used by the Llama FFN: ``silu(g) * up``."""
    return g * jnp.reciprocal(1.0 + jnp.exp(-g)) * up

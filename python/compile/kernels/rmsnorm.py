"""Fused RMSNorm Pallas kernel (pre-attention / pre-FFN norm in Llama).

Forward = Pallas tile over token rows; backward = jnp math via custom_vjp
so training artifacts can differentiate through it.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm", "rmsnorm_pallas"]

EPS = 1e-5


def _rmsnorm_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + EPS) * w_ref[...][None, :]


def rmsnorm_pallas(x, w, *, block_t=64):
    """``x: (t, d)``, ``w: (d,)`` -> ``(t, d)``."""
    t, d = x.shape
    bt = min(block_t, t)
    if t % bt != 0:
        bt = t
    return pl.pallas_call(
        _rmsnorm_kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(x, w)


@jax.custom_vjp
def rmsnorm(x, w):
    """RMSNorm with Pallas forward and jnp backward."""
    return rmsnorm_pallas(x, w)


def _fwd(x, w):
    return rmsnorm_pallas(x, w), (x, w)


def _ref(x, w):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + EPS) * w


def _bwd(res, gy):
    x, w = res
    _, vjp = jax.vjp(_ref, x, w)
    return vjp(gy)


rmsnorm.defvjp(_fwd, _bwd)

"""Model configuration registry shared by L2 lowering and (via
``artifacts/manifest.json``) the Rust coordinator.

``tiny`` is the experiment workhorse (single-CPU-core budget); ``base`` is
a ~90M-parameter configuration proving the stack composes at scale (smoke
runs only — see DESIGN.md §2 substitutions).
"""

import dataclasses

__all__ = ["ModelConfig", "CONFIGS", "TINY", "BASE", "COMBOS"]

# Weight-combination ablation of paper Appendix C.1. Keys are the artifact
# suffixes; values are the subset of {"q", "k", "gate"} that gets cured.
COMBOS = {
    "all": ("q", "k", "gate"),
    "gate": ("gate",),
    "qk": ("q", "k"),
    "qg": ("q", "gate"),
    "kg": ("k", "gate"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A Llama-mini configuration (RMSNorm + RoPE MHA + SiLU-gated FFN)."""

    name: str
    vocab: int = 512
    d_model: int = 256
    n_layers: int = 8
    n_heads: int = 8
    d_inter: int = 704
    seq: int = 64
    batch: int = 8
    rope_theta: float = 10000.0
    # CUR ranks to emit cured/heal artifacts for. Paper uses r_max in
    # {128, 256, 512} on d=4096 (ratios 1/32, 1/16, 1/8); these mirror the
    # ratios at this width. The middle entry is the default.
    ranks: tuple = (8, 16, 32)
    default_rank: int = 16
    # Adapter sizing for the PEFT comparisons (Figs 5-7); see DESIGN.md.
    lora_rank: int = 1
    # Emit full-model (training/healing/task) artifacts? Heavy; tiny only.
    full_model_artifacts: bool = True

    @property
    def d_k(self):
        return self.d_model // self.n_heads

    @property
    def mora_rank(self):
        # MoRA uses a square matrix sized to the dU budget: rm = default
        # rank (dU is r x r, so the budgets match exactly by construction).
        return self.default_rank

    def params_per_layer(self):
        d, di = self.d_model, self.d_inter
        return 4 * d * d + 3 * d * di + 2 * d

    def total_params(self):
        return self.vocab * self.d_model + self.n_layers * self.params_per_layer() + self.d_model


TINY = ModelConfig(name="tiny")

BASE = ModelConfig(
    name="base",
    vocab=2048,
    d_model=768,
    n_layers=12,
    n_heads=12,
    d_inter=2112,
    seq=128,
    batch=4,
    ranks=(32, 64),
    default_rank=64,
    full_model_artifacts=False,
)

CONFIGS = {c.name: c for c in (TINY, BASE)}

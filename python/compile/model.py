"""L2 — the Llama-mini model family in JAX, calling the L1 Pallas kernels.

Everything here is *build-time only*: :mod:`compile.aot` lowers the
functions below to HLO text artifacts that the Rust coordinator loads via
PJRT. Parameters are passed as flat dicts keyed by canonical names (the
manifest fixes the positional order; see aot.py).

Architecture (faithful Llama block, paper Fig. 3):
  x -> RMSNorm -> MHA(RoPE, causal) -> +x -> RMSNorm -> SiLU-gated FFN -> +x

A *cured* block replaces ``W^Q``/``W^K``/``W^Gate`` (per combo) with the
CUR chain evaluated by :func:`kernels.cur_linear`. Full-model training
artifacts use a per-layer *switch* input to select dense vs CUR paths at
runtime, so a single static HLO serves every "compress k layers" choice
the coordinator makes (DESIGN.md §3).
"""

import jax
import jax.numpy as jnp

from . import kernels
from .configs import COMBOS

# ----------------------------------------------------------------- helpers


def flat(x):
    """(b, s, d) -> (b*s, d)."""
    b, s, d = x.shape
    return x.reshape(b * s, d)


def unflat(x2, b, s):
    t, d = x2.shape
    return x2.reshape(b, s, d)


def rmsnorm3(x, w, use_pallas):
    """RMSNorm over the last axis of a (b, s, d) tensor."""
    b, s, _ = x.shape
    if use_pallas:
        return unflat(kernels.rmsnorm(flat(x), w), b, s)
    return unflat(kernels.ref.rmsnorm_ref(flat(x), w), b, s)


def linear3(x, w):
    """Dense projection of a (b, s, d_in) tensor by (d_in, d_out)."""
    return jnp.einsum("bsd,de->bse", x, w)


def cur_linear3(x, c, u, r, use_pallas):
    """CURed projection of a (b, s, m) tensor via the L1 kernel."""
    b, s, _ = x.shape
    fn = kernels.cur_linear if use_pallas else kernels.ref.cur_linear_ref
    return unflat(fn(flat(x), c, u, r), b, s)


# -------------------------------------------------------------------- RoPE


def rope_tables(seq, d_k, theta):
    """Static cos/sin tables, shape (seq, d_k/2) each."""
    half = d_k // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(pos), jnp.sin(pos)


def apply_rope(x, cos, sin):
    """Rotate pairs. x: (b, s, h, d_k); tables broadcast over b, h."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


# --------------------------------------------------------------- attention


def mha(x, q, k, v, wo, cfg):
    """Causal multi-head attention given projected q/k/v, (b, s, d) each."""
    b, s, d = x.shape
    h, dk = cfg.n_heads, cfg.d_k
    q = apply_rope(q.reshape(b, s, h, dk), *rope_tables(s, dk, cfg.rope_theta))
    k = apply_rope(k.reshape(b, s, h, dk), *rope_tables(s, dk, cfg.rope_theta))
    v = v.reshape(b, s, h, dk)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(jnp.float32(dk))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None, None, :, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)  # P_head of the paper
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, s, d)
    return linear3(out, wo)


# ------------------------------------------------------------------ blocks


def proj(x, p, name, use_pallas):
    """Project by weight ``name`` — CUR chain if the cured triple is
    present in ``p``, dense otherwise. Adapters (lora/mora/curlora) add
    their contribution on top when present."""
    if f"c_{name}" in p:
        u = p[f"u_{name}"]
        if f"du_{name}" in p:
            u = u + p[f"du_{name}"]
        y = cur_linear3(x, p[f"c_{name}"], u, p[f"r_{name}"], use_pallas)
    else:
        y = linear3(x, p[f"w_{name}"])
    y = y + adapter_delta(x, p, name)
    return y


def adapter_delta(x, p, name):
    """Sum of any PEFT adapter contributions attached to weight ``name``."""
    delta = 0.0
    if f"lora_a_{name}" in p:
        a, bb = p[f"lora_a_{name}"], p[f"lora_b_{name}"]
        scale = 16.0 / a.shape[1]  # paper App. B: LoRA alpha = 16
        delta = delta + linear3(linear3(x, a), bb) * scale
    if f"mora_m_{name}" in p:
        # MoRA (Jiang et al. 2024), grouped comp/decomp variant: compress
        # the input by summing rm-sized groups, multiply by the square
        # matrix M, expand by tiling. Output dim comes from the dense
        # weight, which is always present in switched blocks.
        m = p[f"mora_m_{name}"]
        rm = m.shape[0]
        b, s, d = x.shape
        xc = x.reshape(b, s, d // rm, rm).sum(axis=2)  # comp
        z = jnp.einsum("bsr,rt->bst", xc, m)
        n_out = p[f"w_{name}"].shape[1]
        delta = delta + jnp.tile(z, (1, 1, n_out // rm))  # decomp
    if f"cl_c_{name}" in p:
        delta = delta + cur_linear3(
            x, p[f"cl_c_{name}"], p[f"cl_u_{name}"], p[f"cl_r_{name}"], False
        )
    return delta


def block(x, p, cfg, use_pallas=True):
    """One transformer block; p holds dense and/or cured entries."""
    h = rmsnorm3(x, p["ln1"], use_pallas)
    q = proj(h, p, "q", use_pallas)
    k = proj(h, p, "k", use_pallas)
    v = linear3(h, p["w_v"])
    x = x + mha(h, q, k, v, p["w_o"], cfg)
    h2 = rmsnorm3(x, p["ln2"], use_pallas)
    g = proj(h2, p, "gate", use_pallas)
    up = linear3(h2, p["w_up"])
    ffn = linear3(jax.nn.silu(g) * up, p["w_down"])
    return x + ffn


def block_switched(x, p, switch, cfg, use_pallas=True):
    """Block whose q/k/gate each compute BOTH dense and CUR paths, blended
    by the runtime ``switch`` scalar (0 = dense, 1 = cured). Gradients of
    the unselected path are zeroed by the multiply, so one artifact serves
    every layer-mask the coordinator picks."""

    def sw_proj(h, name):
        dense = linear3(h, p[f"w_{name}"])
        u = p[f"u_{name}"] + p[f"du_{name}"]
        cur = cur_linear3(h, p[f"c_{name}"], u, p[f"r_{name}"], use_pallas)
        return switch * cur + (1.0 - switch) * dense + adapter_delta(h, p, name)

    h = rmsnorm3(x, p["ln1"], use_pallas)
    q = sw_proj(h, "q")
    k = sw_proj(h, "k")
    v = linear3(h, p["w_v"])
    x = x + mha(h, q, k, v, p["w_o"], cfg)
    h2 = rmsnorm3(x, p["ln2"], use_pallas)
    g = sw_proj(h2, "gate")
    up = linear3(h2, p["w_up"])
    ffn = linear3(jax.nn.silu(g) * up, p["w_down"])
    return x + ffn


def block_calib(x, p, cfg):
    """Dense block that additionally emits the WANDA activation statistics
    (per-feature sum-of-squares of the attention input, feeding W^Q/W^K
    selection, and of the FFN input, feeding W^Gate) plus the raw
    projection inputs themselves (for the Table 6 activation-norm
    analysis)."""
    h = rmsnorm3(x, p["ln1"], True)
    attn_ss = kernels.col_sumsq(flat(h))
    q = linear3(h, p["w_q"])
    k = linear3(h, p["w_k"])
    v = linear3(h, p["w_v"])
    x = x + mha(h, q, k, v, p["w_o"], cfg)
    h2 = rmsnorm3(x, p["ln2"], True)
    ffn_ss = kernels.col_sumsq(flat(h2))
    g = linear3(h2, p["w_gate"])
    up = linear3(h2, p["w_up"])
    ffn = linear3(jax.nn.silu(g) * up, p["w_down"])
    return x + ffn, attn_ss, ffn_ss, h, h2


# ------------------------------------------------------------- embed/head


def embed(tokens, emb):
    return emb[tokens]


def head_logits(x, ln_f, emb, use_pallas=True):
    h = rmsnorm3(x, ln_f, use_pallas)
    return jnp.einsum("bsd,vd->bsv", h, emb)  # tied head


def nll_from_logits(logits, targets):
    """Per-token negative log-likelihood, (b, s)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return logz - tgt


def head_nll(x, ln_f, emb, targets, use_pallas=True):
    return nll_from_logits(head_logits(x, ln_f, emb, use_pallas), targets)


# ------------------------------------------------------------ full models


def middle_layers(cfg):
    """Layers eligible for curing: all but first and last (paper §4.1)."""
    return list(range(1, cfg.n_layers - 1))


def model_dense_logits(tokens, params, cfg, use_pallas=True):
    x = embed(tokens, params["emb"])
    for l in range(cfg.n_layers):
        x = block(x, params[f"layer{l}"], cfg, use_pallas)
    return head_logits(x, params["ln_f"], params["emb"], use_pallas)


def model_switched_logits(tokens, params, switches, cfg, use_pallas=True):
    """Switched model: first/last layers dense, middle layers blended by
    ``switches[l]``; adapters apply wherever present in the layer dict."""
    x = embed(tokens, params["emb"])
    mids = set(middle_layers(cfg))
    for l in range(cfg.n_layers):
        p = params[f"layer{l}"]
        if l in mids:
            x = block_switched(x, p, switches[l], cfg, use_pallas)
        else:
            x = block(x, p, cfg, use_pallas)
    return head_logits(x, params["ln_f"], params["emb"], use_pallas)


# ------------------------------------------------------------------ losses


def ce_loss(logits, targets, weights=None):
    nll = nll_from_logits(logits, targets)
    if weights is None:
        return jnp.mean(nll)
    return jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)


def kd_loss(student_logits, teacher_logits, temperature):
    """Soft-label KL distillation with temperature scaling (paper App. B:
    T = 10), scaled by T^2 as usual so gradients are T-invariant."""
    t = temperature
    pt = jax.nn.softmax(teacher_logits / t, axis=-1)
    ls = jax.nn.log_softmax(student_logits / t, axis=-1)
    lt = jax.nn.log_softmax(teacher_logits / t, axis=-1)
    return jnp.mean(jnp.sum(pt * (lt - ls), axis=-1)) * (t * t)


# ----------------------------------------------------------------- adamw


ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def adamw_update(p, g, m, v, lr, t, weight_decay):
    """One AdamW step (Loshchilov & Hutter); ``t`` is the 1-based step."""
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m / (1.0 - ADAM_B1**t)
    vhat = v / (1.0 - ADAM_B2**t)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + weight_decay * p)
    return p, m, v


def sgd_like_tree_adamw(params, grads, ms, vs, lr, t, weight_decay):
    """Apply AdamW across parallel dicts (same key sets)."""
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        new_p[k], new_m[k], new_v[k] = adamw_update(
            params[k], grads[k], ms[k], vs[k], lr, t, weight_decay
        )
    return new_p, new_m, new_v
